//! `MLCEngine` — the worker-side backend engine.
//!
//! Synchronous, single-threaded core (the runtime's PJRT handles are not
//! `Send`): callers `submit()` requests and drive `step()`; completed
//! work surfaces through `poll_events()`. The worker harness turns this
//! into the paper's message-driven engine; benches and "native mode"
//! drive it directly, which is exactly the MLC-LLM baseline shape.
//!
//! Scheduling policy (vLLM/Sarathi-style continuous batching under TVM's
//! static-shape regime): chunked, prefix-aware prefill co-scheduled with
//! decode, priority-ordered admission, and KV preemption under pool
//! pressure. Each `step_model` resumes/admits whatever fits, in
//! importance order (priority class, then arrival), then runs **at most
//! one positioned prefill chunk** — bounded by
//! [`EngineConfig::prefill_token_budget`], adaptive by default (the
//! whole chunk menu when decode is idle, shrinking as rows pile up),
//! given to the most important of up to
//! [`EngineConfig::max_concurrent_prefills`] `Prefilling` sequences —
//! **and** the batched decode over all running sequences, rounded up to
//! the nearest compiled shapes with garbage-page padding slots. When the
//! page pool runs dry, the least important KV-holding sequence is
//! evicted and later recomputed (vLLM's recompute policy); its
//! sampler/grammar/stream state survives eviction, so the token stream
//! it eventually produces is unchanged. Prompts longer than the largest
//! compiled chunk are fed across steps; a prefix-cache hit starts the
//! first chunk at the cache boundary instead of position 0 (the reused
//! pages are read, not recomputed). The budget knob trades TTFT (big
//! chunks finish prompts sooner) against inter-token latency (small
//! chunks stall the decode batch less per step).

use crate::api::{
    ApiError, ChatChunk, ChatCompletionRequest, ChatCompletionResponse, Choice, FinishReason,
    LogprobEntry, ResponseFormat, Usage,
};
use crate::browser::{BrowserConfig, BrowserEnv};
use crate::grammar::{
    parse_ebnf, schema_to_grammar, CompiledGrammar, Grammar, GrammarMatcher, MaskCache,
    TokenBitmask, VocabTrie,
};
use crate::json::Value;
use crate::kvcache::{AllocError, KvCacheManager};
use crate::lru::LruMap;
use crate::metrics::EngineStats;
use crate::models::Manifest;
use crate::runtime::{
    thread_client, FaultClass, FaultInjectingBackend, FaultPlan, ModelBackend, ModelRuntime,
    ReferenceBackend, RuntimeError,
};
use crate::sampler::{branch_seed, LogitsProcessor, Pcg32, SampleScratch, SamplingParams};
use crate::tokenizer::{render_chat, StreamDecoder, Tokenizer};
use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::rc::Rc;
use std::time::{Duration, Instant};

pub type RequestId = u64;

/// Which [`ModelBackend`] implementation the engine loads models on.
#[derive(Clone, Debug)]
pub enum BackendKind {
    /// Compiled AOT artifacts executed through the PJRT client
    /// (requires `make artifacts`); the production path.
    Xla,
    /// Pure-Rust seeded-deterministic reference backend — no artifacts,
    /// runs anywhere. Models come from the built-in reference registry
    /// (`tiny-ref`, `tiny-ref-b`). `seed` fixes every logit the models
    /// will ever produce.
    Reference { seed: u64 },
}

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    /// Models to load at startup (multi-model engines are first-class,
    /// §2.1 "loading multiple models in the same engine").
    pub models: Vec<String>,
    /// `Some` => browser mode (inject WebGPU/WASM overheads).
    pub browser: Option<BrowserConfig>,
    pub enable_prefix_cache: bool,
    /// Execution backend (see [`BackendKind`]).
    pub backend: BackendKind,
    /// Automaton states cached per grammar (see `grammar::MaskCache`);
    /// clamped to at least 1.
    pub mask_cache_capacity: usize,
    /// Chunked-prefill token budget: the most prompt tokens one scheduler
    /// step spends on prefill before running the decode batch. Clamped to
    /// the model's compiled chunk menu (`ModelConfig::next_prefill_tokens`),
    /// so any value is safe; smaller budgets bound the per-step decode
    /// stall (better ITL under long-prompt admission), larger budgets
    /// finish prompts in fewer steps (better TTFT).
    pub prefill_token_budget: usize,
    /// Speculative decoding: a cheaper model that proposes
    /// [`Self::spec_tokens`] tokens per step for the target to verify in
    /// one positioned batch call. `None` (the default) decodes one token
    /// per model call. Verification re-samples every position with the
    /// request's own sampler, so output is token-for-token what plain
    /// decode would have produced.
    pub draft_model: Option<String>,
    /// Tokens the draft proposes per speculation round; clamped to ≥ 1.
    pub spec_tokens: usize,
    /// Scale each speculation round's proposal count to the request's
    /// observed acceptance rate (an EWMA): high-accept requests keep
    /// proposing [`Self::spec_tokens`], low-accept ones shrink toward 1
    /// and stop paying for verify rows the sampler rejects. Verification
    /// re-samples every position either way, so this never changes
    /// output bytes — only how many tokens one model call yields. On by
    /// default; turn off for fixed-`k` speculation.
    pub adaptive_spec_tokens: bool,
    /// Emit grammar-forced token runs (states whose masks allow exactly
    /// one token) without model or sampler calls. On by default; turn
    /// off for the strict one-model-call-per-token baseline.
    pub enable_fast_forward: bool,
    /// Concurrent `Prefilling` sequences per model — admissions whose
    /// prompts are still being chunked. Each step still runs at most one
    /// chunk per model; more slots mean new admissions overlap a long
    /// prompt's chunking instead of queueing behind it. Clamped to ≥ 1.
    pub max_concurrent_prefills: usize,
    /// Sarathi-style adaptive chunk budget: scale
    /// [`Self::prefill_token_budget`] by the live decode batch (see
    /// `ModelConfig::adaptive_prefill_budget`) — spend the whole chunk
    /// menu when no decode rows can stall, shrink chunks as the batch
    /// grows. Off = the configured budget applies verbatim every step.
    pub adaptive_prefill: bool,
    /// Admission back-pressure: per-model cap on queued (not yet
    /// admitted) requests. At the cap, `submit` fails fast with a 429
    /// `queue_full` error instead of queueing unboundedly; the HTTP
    /// layer adds a `Retry-After` header. Clamped to ≥ 1.
    pub max_waiting_requests: usize,
    /// Deterministic fault schedule wrapped around every *target*
    /// backend ([`crate::runtime::FaultInjectingBackend`]) — the offline
    /// analog of WebGPU device unreliability, for chaos tests and
    /// benches. `None` (the default) runs the backends bare.
    pub fault_plan: Option<FaultPlan>,
    /// Default per-request deadline in ms (`--request-timeout`); a
    /// request's own `deadline_ms` overrides it. Past the deadline the
    /// scheduler fails the request with a structured `timeout_error`.
    /// `None` = no default deadline.
    pub request_timeout_ms: Option<u64>,
    /// Stuck-step watchdog: a scheduler step that completes but takes
    /// longer than this (a stalling backend) increments
    /// `watchdog_stalls`. Clamped to ≥ 1 ms.
    pub watchdog_step_ms: u64,
    /// How long the worker/HTTP layers wait on an engine channel before
    /// returning a structured `timeout_error` (`--engine-timeout`).
    pub engine_timeout_ms: u64,
}

impl EngineConfig {
    pub fn native(models: &[&str]) -> Self {
        Self {
            artifacts_dir: crate::artifacts_dir(),
            models: models.iter().map(|s| s.to_string()).collect(),
            browser: None,
            enable_prefix_cache: true,
            backend: BackendKind::Xla,
            mask_cache_capacity: DEFAULT_MASK_CACHE_CAPACITY,
            prefill_token_budget: DEFAULT_PREFILL_TOKEN_BUDGET,
            draft_model: None,
            spec_tokens: DEFAULT_SPEC_TOKENS,
            adaptive_spec_tokens: true,
            enable_fast_forward: true,
            max_concurrent_prefills: DEFAULT_MAX_CONCURRENT_PREFILLS,
            adaptive_prefill: true,
            max_waiting_requests: DEFAULT_MAX_WAITING_REQUESTS,
            fault_plan: None,
            request_timeout_ms: None,
            watchdog_step_ms: DEFAULT_WATCHDOG_STEP_MS,
            engine_timeout_ms: DEFAULT_ENGINE_TIMEOUT_MS,
        }
    }

    /// The channel-wait bound as a `Duration` (worker ready-handshake,
    /// worker/HTTP event waits).
    pub fn engine_timeout(&self) -> Duration {
        Duration::from_millis(self.engine_timeout_ms.max(1))
    }

    pub fn browser(models: &[&str]) -> Self {
        Self { browser: Some(BrowserConfig::default()), ..Self::native(models) }
    }

    /// Native-mode engine on the deterministic reference backend: no
    /// artifacts, no filesystem — the configuration every integration
    /// test runs on. Struct-update from [`Self::native`] so future
    /// defaults can't drift between the two.
    pub fn reference(models: &[&str]) -> Self {
        Self {
            artifacts_dir: PathBuf::new(),
            backend: BackendKind::Reference { seed: 0x5EED_CAFE },
            ..Self::native(models)
        }
    }

    /// Browser-mode engine on the reference backend.
    pub fn reference_browser(models: &[&str]) -> Self {
        Self { browser: Some(BrowserConfig::default()), ..Self::reference(models) }
    }
}

/// One loaded model: name, target backend, optional draft backend.
type LoadedModel = (String, Box<dyn ModelBackend>, Option<Box<dyn ModelBackend>>);

/// What [`MLCEngine::load_backends`] resolves a config into.
type LoadedModels = (Rc<Tokenizer>, Vec<LoadedModel>);

/// Completion events drained via `poll_events`.
#[derive(Debug)]
pub enum EngineEvent {
    Chunk(RequestId, ChatChunk),
    Done(RequestId, ChatCompletionResponse),
    Error(RequestId, ApiError),
}

/// Per-*branch* state of a decode row. For the common `n = 1` request
/// this is just the generation half of the request; an `n > 1` request
/// fans out into `n` of these at the end of its (single) prefill — each
/// with its own KV sequence (page-level copy-on-write fork of the
/// parent's), sampler RNG and penalty state (branch-mixed seed, see
/// [`crate::sampler::branch_seed`]), grammar matcher, stream decoder,
/// and stop/finish state. Everything branches share — the request
/// identity, scheduling class, sampling template, limits — stays on
/// [`RunningSeq`].
struct BranchState {
    /// Choice index within the request (0 for `n = 1`).
    index: usize,
    seq_id: u64,
    processor: LogitsProcessor,
    matcher: Option<GrammarMatcher>,
    decoder: StreamDecoder,
    /// Full decoded text so far.
    text: String,
    /// Bytes of `text` already emitted as stream deltas.
    emitted: usize,
    completion_tokens: usize,
    logprobs: Option<Vec<LogprobEntry>>,
    finish: Option<FinishReason>,
    /// Structured per-branch failure (data-plane fault, lost KV
    /// residency): the owning scheduling loop routes it to
    /// [`MLCEngine::fail`] instead of finalizing normally.
    failed: Option<ApiError>,
}

/// One decode row: one branch of a request plus the request-level state
/// every branch shares. An `n = 1` request is exactly one of these; an
/// `n > 1` request becomes `n` after fan-out, aggregated through
/// [`FamilyState`].
struct RunningSeq {
    req_id: RequestId,
    model: String,
    /// Scheduling class (from the request): orders admission and chunk
    /// allocation, and — inverted — victim selection for preemption.
    /// Ties break by arrival order (`req_id`).
    priority: i32,
    /// Requested parallel choices (`n`); fan-out happens once, at the
    /// end of the request's single prefill pass.
    n_branches: usize,
    /// Sampling template the request arrived with — branch `i`'s
    /// processor is rebuilt from it with a branch-mixed seed at fork.
    sampling: SamplingParams,
    /// Fallback sampler seed (per-request nonce) when the request sets
    /// none; branch mixing applies to whichever seed is effective.
    fallback_seed: u64,
    mask_cache: Option<Rc<RefCell<MaskCache>>>,
    /// Shared per-grammar cache of forced-token runs keyed by start-state
    /// fingerprint (see [`MLCEngine::fast_forward`]).
    forced_runs: Option<Rc<RefCell<LruMap<u64, Rc<Vec<u32>>>>>>,
    prompt_tokens: usize,
    max_tokens: usize,
    stop: Vec<String>,
    stream: bool,
    t_admit: Instant,
    t_prefilled: Option<Instant>,
    /// Deadline (admission time + effective `deadline_ms`); past it the
    /// scheduler fails the request with a structured `timeout_error`.
    deadline: Option<Instant>,
    /// Speculative-decoding acceptance EWMA for this branch; drives the
    /// adaptive per-round proposal count (see
    /// [`EngineConfig::adaptive_spec_tokens`]). Starts optimistic so the
    /// first rounds propose the configured maximum.
    accept_ewma: f64,
    branch: BranchState,
}

/// Aggregation state for one `n > 1` request after fan-out: branches
/// resolve independently (finish, fail, abort — in any order, under any
/// preemption schedule) and the request's single terminal `Done`/`Error`
/// event fires when the last one does. Created only at fork time, so a
/// request that dies before fan-out resolves through the ordinary
/// single-sequence path.
struct FamilyState {
    /// Branches this family is waiting on.
    expected: usize,
    /// Branches that have finished or failed.
    resolved: usize,
    /// Finished choices, slotted by branch index.
    choices: Vec<Option<Choice>>,
    /// First branch failure; a failed family reports one error and
    /// discards partial choices.
    error: Option<ApiError>,
    /// Aggregate usage: prompt counted once, completions summed, timings
    /// from the slowest branch. Rates are computed at completion.
    usage: Usage,
}

struct PendingReq {
    req_id: RequestId,
    req: ChatCompletionRequest,
    prompt_ids: Vec<u32>,
    t_admit: Instant,
}

/// Persistent decode-step input buffers, one set per model. The decode hot
/// path refills these in place every step instead of allocating four fresh
/// vectors per token batch.
#[derive(Default)]
struct StepBuffers {
    ids: Vec<i32>,
    positions: Vec<i32>,
    seq_lens: Vec<i32>,
    tables: Vec<i32>,
}

impl StepBuffers {
    /// Size for `batch` rows of `mp` pages each, zero-filled (padding rows
    /// must read as seq_len 0 / position 0 / garbage-page tables).
    fn reset(&mut self, batch: usize, mp: usize) {
        self.ids.clear();
        self.ids.resize(batch, 0);
        self.positions.clear();
        self.positions.resize(batch, 0);
        self.seq_lens.clear();
        self.seq_lens.resize(batch, 0);
        self.tables.clear();
        self.tables.resize(batch * mp, 0);
    }
}

/// A sequence in the `Prefilling` state: admitted (KV pages allocated,
/// grammar compiled, processor seeded) but its prompt not yet fully
/// computed. Each step, `step_model` feeds the most important prefilling
/// sequence one budget-sized positioned chunk (round-robin within a
/// priority class) until `next_pos` reaches `prefill_end`. A fresh
/// admission then samples its first token from the final chunk's logits
/// and joins the decode batch; a resumed preemption victim rejoins the
/// batch directly — its next decode input was sampled before eviction.
/// Up to [`EngineConfig::max_concurrent_prefills`] per model; the
/// per-step prefill cost stays bounded by one chunk regardless.
struct PrefillingSeq {
    seq: RunningSeq,
    /// For a fresh admission: the prompt. For a resumed victim: its full
    /// token history (prompt + generated) captured at preemption.
    prompt_ids: Vec<u32>,
    /// Next absolute position to compute. Starts at the prefix-cache
    /// skip boundary ([`crate::kvcache::Sequence::prefill_start`]), not 0.
    next_pos: usize,
    /// One past the last position this prefill computes:
    /// `prompt_ids.len()` for fresh admissions (the final chunk's logits
    /// seed the first sampled token), `prompt_ids.len() - 1` for resumed
    /// victims (the last token is the next decode call's input and
    /// writes its own KV there).
    prefill_end: usize,
}

/// A sequence evicted under page-pool pressure: its KV residency was
/// freed (fully written full pages parked in the prefix cache), but its
/// sampler, grammar, and stream state live on in `seq`. Resuming
/// re-admits the token history and recomputes `[prefix-cache boundary,
/// prefill_end)` through the ordinary chunked-prefill path; the
/// `written` watermark machinery makes that recompute reproduce exactly
/// the KV the sequence lost, so its token stream is unchanged (pinned by
/// tests/test_preemption.rs).
struct PreemptedSeq {
    seq: RunningSeq,
    /// Full token history (prompt + generated) at preemption.
    tokens: Vec<u32>,
    /// Pool-written positions at preemption — the most a resume can have
    /// to recompute (`preempted_tokens_recomputed` accounting).
    computed: usize,
    /// Whether the victim had already sampled its next decode input
    /// (evicted from the decode batch, or mid-resume). If not, it was
    /// mid-prefill and the resume still samples its first token.
    sampled: bool,
}

/// The speculative-decoding draft: a second, cheaper backend shadowing a
/// target model. Its KV manager mirrors each running sequence's token
/// window (rolled back past rejected proposals via
/// [`KvCacheManager::truncate`]); its own RNG drives proposal choices so
/// the request's sampler stream — the thing that makes verification
/// output-identical to plain decode — is never touched here.
struct DraftModel {
    backend: Box<dyn ModelBackend>,
    kv: KvCacheManager,
    rng: Pcg32,
}

struct EngineModel {
    backend: Box<dyn ModelBackend>,
    kv: KvCacheManager,
    /// `Some` when the engine was configured with a draft model; flips
    /// `decode_batch` over to the speculative path.
    draft: Option<DraftModel>,
    waiting: VecDeque<PendingReq>,
    prefilling: VecDeque<PrefillingSeq>,
    /// Victims evicted under page-pool pressure, awaiting re-admission.
    preempted: VecDeque<PreemptedSeq>,
    running: Vec<RunningSeq>,
    step: StepBuffers,
}

/// One compiled grammar shared across requests: the AOT vocabulary
/// partition, the LRU mask cache over its residue, and the forced-run
/// cache for fast-forward. Cloning is three `Rc` bumps — every sequence
/// of every request using the same grammar (and each row of a
/// multi-sequence request) shares all of them.
#[derive(Clone)]
struct GrammarEntry {
    compiled: Rc<CompiledGrammar>,
    cache: Rc<RefCell<MaskCache>>,
    runs: Rc<RefCell<LruMap<u64, Rc<Vec<u32>>>>>,
}

/// Distinct compiled grammars retained by the engine. Each entry pins a
/// residue trie plus up to [`EngineConfig::mask_cache_capacity`]
/// vocab-sized masks, so the map is LRU-bounded: traffic with unbounded
/// distinct schemas can't grow engine memory forever (in-flight
/// sequences keep their evicted entry alive through their own `Rc`s).
const MAX_COMPILED_GRAMMARS: usize = 32;

/// Default for [`EngineConfig::mask_cache_capacity`].
pub const DEFAULT_MASK_CACHE_CAPACITY: usize = 256;

/// Default for [`EngineConfig::prefill_token_budget`] — sized for
/// real-model chunk menus (hundreds to thousands of tokens); on the tiny
/// reference models it clamps to the largest compiled chunk, preserving
/// the old one-chunk-per-prompt behavior for short prompts.
pub const DEFAULT_PREFILL_TOKEN_BUDGET: usize = 2048;

/// Default for [`EngineConfig::spec_tokens`].
pub const DEFAULT_SPEC_TOKENS: usize = 4;

/// Default for [`EngineConfig::max_concurrent_prefills`].
pub const DEFAULT_MAX_CONCURRENT_PREFILLS: usize = 4;

/// Default for [`EngineConfig::max_waiting_requests`].
pub const DEFAULT_MAX_WAITING_REQUESTS: usize = 256;

/// Default for [`EngineConfig::watchdog_step_ms`] — far above any sane
/// step, so only a genuinely wedged backend trips it.
pub const DEFAULT_WATCHDOG_STEP_MS: u64 = 30_000;

/// Default for [`EngineConfig::engine_timeout_ms`] (the old hardcoded
/// 600 s channel waits).
pub const DEFAULT_ENGINE_TIMEOUT_MS: u64 = 600_000;

/// Bounded in-place retries for a transiently-failing backend op before
/// escalating to a device reset.
const MAX_TRANSIENT_RETRIES: u32 = 3;

/// Longest forced-token run emitted per fast-forward cache entry;
/// longer chains continue from the next state's entry.
const MAX_FF_RUN: usize = 64;

/// Forced-run cache entries retained per grammar, keyed by start-state
/// fingerprint. Runs are at most [`MAX_FF_RUN`] token ids, so the bound
/// is generous.
const FORCED_RUN_CACHE_CAPACITY: usize = 256;

/// Seed for the draft models' proposal RNG. Draft choices must never
/// consume the request's own sampler stream — that separation is what
/// keeps speculative output identical to plain decode.
const DRAFT_SEED: u64 = 0xD12A_F75E;

/// The backend engine. See module docs.
pub struct MLCEngine {
    tokenizer: Rc<Tokenizer>,
    trie: Rc<VocabTrie>,
    models: BTreeMap<String, EngineModel>,
    env: Option<Rc<BrowserEnv>>,
    /// Compiled grammars + mask caches keyed by grammar identity,
    /// LRU-bounded at [`MAX_COMPILED_GRAMMARS`] entries.
    grammar_caches: LruMap<String, GrammarEntry>,
    /// Per-grammar mask-cache capacity (from the config, min 1).
    mask_cache_capacity: usize,
    /// Chunked-prefill token budget (from the config; clamped to each
    /// model's compiled chunk menu at use).
    prefill_token_budget: usize,
    /// Adaptive prefill-budget toggle (from the config).
    adaptive_prefill: bool,
    /// Concurrent `Prefilling` admissions per model (from the config,
    /// min 1).
    max_concurrent_prefills: usize,
    /// Per-model waiting-queue cap (from the config, min 1).
    max_waiting_requests: usize,
    /// Draft proposals per speculation round (from the config, min 1).
    spec_tokens: usize,
    /// Acceptance-adaptive speculation toggle (from the config).
    adaptive_spec_tokens: bool,
    /// Grammar fast-forward toggle (from the config).
    enable_fast_forward: bool,
    /// Fan-out aggregation for in-flight `n>1` requests, keyed by
    /// request id; entries exist only between fork and the terminal
    /// `Done`/`Error` event.
    families: BTreeMap<RequestId, FamilyState>,
    /// Default per-request deadline (from the config).
    request_timeout_ms: Option<u64>,
    /// Stuck-step watchdog threshold (from the config, min 1 ms).
    watchdog_step_ms: u64,
    /// Graceful-shutdown mode: admission stopped, residents running down.
    draining: bool,
    /// When set, residents still unfinished past this instant are failed
    /// (`drain_failed`) so shutdown is bounded.
    drain_deadline: Option<Instant>,
    /// Candidate scratch shared by every sequence's sampling calls: one
    /// set of buffers serves all rows of the decode batch.
    scratch: SampleScratch,
    events: VecDeque<EngineEvent>,
    next_req: RequestId,
    next_seq: u64,
    nonce: u64,
    stats: EngineStats,
    eos_ids: Vec<u32>,
}

impl MLCEngine {
    /// Load every configured model on the configured backend (XLA:
    /// compiles AOT artifacts, one-time cost, the "model loading" phase
    /// of the paper's Figure 1; reference: instant, in-process).
    pub fn new(cfg: &EngineConfig) -> Result<Self, ApiError> {
        let env = cfg.browser.clone().map(|b| Rc::new(BrowserEnv::new(b)));
        let (tokenizer, backends) = Self::load_backends(cfg, env.as_deref())?;
        let trie = Rc::new(VocabTrie::build(tokenizer.vocab_size(), |i| {
            tokenizer.token_bytes(i)
        }));

        let mut models = BTreeMap::new();
        for (name, backend, draft) in backends {
            let mc = backend.config().clone();
            let mut kv = KvCacheManager::new(
                mc.num_pages,
                mc.page_size,
                mc.max_pages_per_seq(),
                cfg.enable_prefix_cache,
            );
            // With a backend page-copy primitive, fork tails and CoW
            // un-shares are physical copies; without one the manager
            // clamps `written` and the flush path recomputes instead.
            kv.set_page_copy(backend.supports_page_copy());
            let draft = draft.map(|b| {
                let dc = b.config().clone();
                // The mirror tracks one rolling window per sequence;
                // prefix reuse there would only re-register pages the
                // next rollback invalidates.
                let kv = KvCacheManager::new(
                    dc.num_pages,
                    dc.page_size,
                    dc.max_pages_per_seq(),
                    false,
                );
                DraftModel { backend: b, kv, rng: Pcg32::new(DRAFT_SEED) }
            });
            models.insert(
                name,
                EngineModel {
                    backend,
                    kv,
                    draft,
                    waiting: VecDeque::new(),
                    prefilling: VecDeque::new(),
                    preempted: VecDeque::new(),
                    running: Vec::new(),
                    step: StepBuffers::default(),
                },
            );
        }
        let eos_ids = ["<eos>", "<|end|>"]
            .iter()
            .filter_map(|s| tokenizer.special_id(s))
            .collect();
        Ok(Self {
            tokenizer,
            trie,
            models,
            env,
            grammar_caches: LruMap::new(MAX_COMPILED_GRAMMARS),
            mask_cache_capacity: cfg.mask_cache_capacity.max(1),
            prefill_token_budget: cfg.prefill_token_budget.max(1),
            adaptive_prefill: cfg.adaptive_prefill,
            max_concurrent_prefills: cfg.max_concurrent_prefills.max(1),
            max_waiting_requests: cfg.max_waiting_requests.max(1),
            spec_tokens: cfg.spec_tokens.max(1),
            adaptive_spec_tokens: cfg.adaptive_spec_tokens,
            enable_fast_forward: cfg.enable_fast_forward,
            families: BTreeMap::new(),
            request_timeout_ms: cfg.request_timeout_ms,
            watchdog_step_ms: cfg.watchdog_step_ms.max(1),
            draining: false,
            drain_deadline: None,
            scratch: SampleScratch::new(),
            events: VecDeque::new(),
            next_req: 1,
            next_seq: 1,
            nonce: 0x5eed,
            stats: EngineStats::new(),
            eos_ids,
        })
    }

    /// Resolve the configured backend into (tokenizer, one target backend
    /// per model plus its optional speculative-draft backend). The XLA arm
    /// reads the artifacts manifest; the reference arm builds everything
    /// from the in-code registry. Each target gets its own draft instance
    /// so multi-model engines never share draft KV state.
    fn load_backends(
        cfg: &EngineConfig,
        env: Option<&BrowserEnv>,
    ) -> Result<LoadedModels, ApiError> {
        let mut backends: Vec<LoadedModel> = Vec::new();
        let tokenizer = match &cfg.backend {
            BackendKind::Xla => {
                let manifest = Manifest::load(&cfg.artifacts_dir)
                    .map_err(|e| ApiError::internal(format!("manifest: {e}")))?;
                let tokenizer = Rc::new(
                    Tokenizer::from_file(&manifest.tokenizer_path)
                        .map_err(|e| ApiError::internal(format!("tokenizer: {e}")))?,
                );
                let client = thread_client().map_err(|e| ApiError::internal(e.to_string()))?;
                for name in &cfg.models {
                    let runtime = ModelRuntime::load(
                        &client,
                        &manifest,
                        name,
                        env.map(|e| BrowserEnv::new(e.config().clone())),
                    )
                    .map_err(|e| ApiError::internal(format!("load {name}: {e}")))?;
                    let draft = match &cfg.draft_model {
                        Some(dname) => {
                            let d = ModelRuntime::load(
                                &client,
                                &manifest,
                                dname,
                                env.map(|e| BrowserEnv::new(e.config().clone())),
                            )
                            .map_err(|e| {
                                ApiError::internal(format!("load draft {dname}: {e}"))
                            })?;
                            Some(Box::new(d) as Box<dyn ModelBackend>)
                        }
                        None => None,
                    };
                    backends.push((name.clone(), Box::new(runtime), draft));
                }
                tokenizer
            }
            BackendKind::Reference { seed } => {
                let tokenizer = Rc::new(crate::models::reference_tokenizer());
                let stop_token = tokenizer.special_id("<eos>");
                for name in &cfg.models {
                    let mc = crate::models::reference_model_config(name)
                        .map_err(ApiError::not_found)?;
                    let backend = ReferenceBackend::new(
                        mc,
                        *seed,
                        stop_token,
                        env.map(|e| BrowserEnv::new(e.config().clone())),
                    );
                    let draft = match &cfg.draft_model {
                        Some(dname) => {
                            let dc = crate::models::reference_model_config(dname)
                                .map_err(ApiError::not_found)?;
                            let d = ReferenceBackend::new(
                                dc,
                                *seed,
                                stop_token,
                                env.map(|e| BrowserEnv::new(e.config().clone())),
                            );
                            Some(Box::new(d) as Box<dyn ModelBackend>)
                        }
                        None => None,
                    };
                    backends.push((name.clone(), Box::new(backend), draft));
                }
                tokenizer
            }
        };
        // Chaos harness: wrap every *target* backend in the fault
        // injector (drafts stay bare — their failures already soft-fail
        // into plain decode). The wrapper delegates config/shape
        // queries, so nothing downstream can tell until a fault fires.
        if let Some(plan) = &cfg.fault_plan {
            backends = backends
                .into_iter()
                .map(|(name, target, draft)| {
                    let target: Box<dyn ModelBackend> =
                        Box::new(FaultInjectingBackend::new(target, plan.clone()));
                    (name, target, draft)
                })
                .collect();
        }
        // A draft proposes token ids the target must be able to verify:
        // the vocabularies have to line up exactly.
        for (name, backend, draft) in &backends {
            if let Some(d) = draft {
                let (tv, dv) = (backend.config().vocab_size, d.config().vocab_size);
                if tv != dv {
                    return Err(ApiError::invalid(format!(
                        "draft model vocab ({dv}) does not match target '{name}' vocab ({tv})"
                    )));
                }
            }
        }
        Ok((tokenizer, backends))
    }

    pub fn tokenizer(&self) -> &Rc<Tokenizer> {
        &self.tokenizer
    }

    /// The engine's accumulated counters. The `grammar_mask_*` fields
    /// are *not* live here — the mask caches are their source of truth
    /// while the engine runs; read [`MLCEngine::stats_json`] (which folds
    /// the live cache counters into its snapshot) for those.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    pub fn loaded_models(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    pub fn browser_env(&self) -> Option<&Rc<BrowserEnv>> {
        self.env.as_ref()
    }

    /// Queue a request. Errors here are synchronous (bad request / unknown
    /// model / prompt too long); execution errors surface as events.
    pub fn submit(&mut self, req: ChatCompletionRequest) -> Result<RequestId, ApiError> {
        // Draining: admission is closed, full stop. 503 + Retry-After at
        // the HTTP layer; residents keep streaming to completion.
        if self.draining {
            self.stats.drain_rejected += 1;
            return Err(ApiError::unavailable("engine is draining; no new requests accepted"));
        }
        req.sampling.validate().map_err(ApiError::invalid)?;
        let model = self
            .models
            .get(&req.model)
            .ok_or_else(|| ApiError::not_found(format!("model '{}' not loaded", req.model)))?;
        if req.messages.is_empty() {
            return Err(ApiError::invalid("messages must be non-empty"));
        }
        // Every branch of an `n>1` fan-out is its own decode row; the
        // family can never fit a batch smaller than `n`.
        if req.n == 0 {
            return Err(ApiError::invalid("'n' must be >= 1"));
        }
        let max_batch = model.backend.config().max_decode_batch();
        if req.n > max_batch {
            return Err(ApiError::invalid(format!(
                "'n' ({}) exceeds model '{}' max decode batch ({max_batch})",
                req.n, req.model
            )));
        }
        // Back-pressure: bounded waiting queue, reject-fast over
        // queue-forever. 429 + Retry-After at the HTTP layer.
        if model.waiting.len() >= self.max_waiting_requests {
            return Err(ApiError::queue_full(format!(
                "model '{}' has {} queued requests (cap {}); retry later",
                req.model,
                model.waiting.len(),
                self.max_waiting_requests
            )));
        }

        // Tokenize the chat template (a WASM-side CPU stage in the paper).
        let tokenizer = self.tokenizer.clone();
        let messages = req.messages.clone();
        let prompt_ids = match &self.env {
            Some(env) => env.cpu_stage(|| render_chat(&tokenizer, &messages)),
            None => render_chat(&tokenizer, &messages),
        };

        // No compiled-chunk-size cap here: prompts longer than the largest
        // compiled chunk are fed across steps as positioned chunks. The
        // only hard limit left is the model's context length.
        let mc = model.backend.config();
        if prompt_ids.len() + 1 >= mc.max_seq_len {
            return Err(ApiError::invalid("prompt exceeds model context length"));
        }
        // Validate the grammar up front so errors are synchronous.
        self.build_grammar(&req.response_format)?;

        let req_id = self.next_req;
        self.next_req += 1;
        let pending = PendingReq { req_id, req, prompt_ids, t_admit: Instant::now() };
        self.models
            .get_mut(&pending.req.model)
            .unwrap()
            .waiting
            .push_back(pending);
        Ok(req_id)
    }

    /// Abort a queued or running request. After an `n>1` fan-out the
    /// request is several branches spread across the scheduler queues
    /// (some may be preempted while others decode); every one is marked,
    /// so the family resolves completely and no branch's pages leak.
    pub fn abort(&mut self, req_id: RequestId) {
        for (_, m) in self.models.iter_mut() {
            if let Some(idx) = m.waiting.iter().position(|p| p.req_id == req_id) {
                m.waiting.remove(idx);
                self.events.push_back(EngineEvent::Error(
                    req_id,
                    ApiError { status: 499, kind: "aborted".into(), message: "aborted".into() },
                ));
                return;
            }
            for pf in m.prefilling.iter_mut().filter(|p| p.seq.req_id == req_id) {
                // Mid-prefill: resolved (no further chunks run) on the
                // model's next scheduler step.
                pf.seq.branch.finish = Some(FinishReason::Abort);
            }
            for p in m.preempted.iter_mut().filter(|p| p.seq.req_id == req_id) {
                // Evicted: pages already freed; resolved instead of
                // resumed on the model's next scheduler step.
                p.seq.branch.finish = Some(FinishReason::Abort);
            }
            for seq in m.running.iter_mut().filter(|s| s.req_id == req_id) {
                seq.branch.finish = Some(FinishReason::Abort);
            }
        }
    }

    /// Forcibly evict a request's KV residency (a test/diagnostic hook —
    /// the scheduler invokes the same machinery on its own under pool
    /// pressure). The sequence keeps its sampler/grammar/stream state
    /// and resumes via recompute on a later step, so its token stream is
    /// unchanged. Returns false when the request holds no pages
    /// (waiting, already evicted, finished, or unknown).
    pub fn preempt(&mut self, req_id: RequestId) -> bool {
        let names: Vec<String> = self.models.keys().cloned().collect();
        for name in names {
            let m = &self.models[&name];
            if let Some(i) =
                m.running.iter().position(|s| s.req_id == req_id && s.branch.finish.is_none())
            {
                self.preempt_at(&name, true, i);
                return true;
            }
            if let Some(i) = m
                .prefilling
                .iter()
                .position(|p| p.seq.req_id == req_id && p.seq.branch.finish.is_none())
            {
                self.preempt_at(&name, false, i);
                return true;
            }
        }
        false
    }

    pub fn has_work(&self) -> bool {
        self.models.values().any(|m| {
            !m.waiting.is_empty()
                || !m.prefilling.is_empty()
                || !m.preempted.is_empty()
                || !m.running.is_empty()
        })
    }

    pub fn poll_events(&mut self) -> Vec<EngineEvent> {
        self.events.drain(..).collect()
    }

    /// Drive the engine until idle (convenience for sync callers).
    pub fn run_to_completion(&mut self) -> Result<(), ApiError> {
        while self.has_work() {
            self.step()?;
        }
        Ok(())
    }

    /// Submit + run + return the single response (the non-streaming
    /// "endpoint" call; used by native-mode benches and tests).
    pub fn chat_completion(
        &mut self,
        req: ChatCompletionRequest,
    ) -> Result<ChatCompletionResponse, ApiError> {
        let id = self.submit(req)?;
        self.run_to_completion()?;
        for ev in self.poll_events() {
            match ev {
                EngineEvent::Done(rid, resp) if rid == id => return Ok(resp),
                EngineEvent::Error(rid, e) if rid == id => return Err(e),
                _ => {}
            }
        }
        Err(ApiError::internal("request produced no completion"))
    }

    /// One scheduler step per model: admit into the `Prefilling` slot if
    /// it is free, run at most one budget-bounded prefill chunk, then run
    /// the batched decode — prefill and decode share the step instead of
    /// excluding each other.
    pub fn step(&mut self) -> Result<(), ApiError> {
        let names: Vec<String> = self.models.keys().cloned().collect();
        for name in names {
            self.expire_deadlines(&name);
            let t0 = Instant::now();
            let result = self.step_model(&name);
            if t0.elapsed() >= Duration::from_millis(self.watchdog_step_ms) {
                // The step completed but blew past the watchdog bound —
                // a stalling backend. Counted, not failed: the work did
                // land, and operators alert on the counter.
                self.stats.watchdog_stalls += 1;
            }
            if let Err(e) = result {
                // Recoverable faults (transient exhaustion, device loss)
                // are absorbed here: `step()` returns `Err` only for
                // genuine internal bugs, never for hardware misbehaving.
                self.recover(&name, e)
                    .map_err(|e| ApiError::internal(format!("{name}: {e}")))?;
            }
            self.enforce_drain(&name);
        }
        Ok(())
    }

    /// Route a failed `step_model` by fault class. Transient errors are
    /// normally absorbed in place by [`with_retries`] and arrive here
    /// only escalated (retry budget exhausted → `DeviceLost`) or from an
    /// unwrapped path; either way the conservative answer is a device
    /// reset — surviving streams recompute and stay byte-identical.
    /// Internal errors (shape bugs, artifact mismatches) still fail the
    /// step: retrying a logic error just loops.
    fn recover(&mut self, name: &str, e: RuntimeError) -> Result<(), RuntimeError> {
        match e.class() {
            FaultClass::Transient | FaultClass::DeviceLost => self.device_reset(name),
            FaultClass::Internal => Err(e),
        }
    }

    /// Device-loss recovery, the offline analog of re-requesting a
    /// GPUDevice after `device.lost`: capture every resident sequence's
    /// token history and sampler/grammar/stream state (the preemption
    /// machinery), discard ALL pool metadata — the lost device's pages
    /// must never be parked for prefix reuse — and reset the backend.
    /// Residents re-enter through `admit_and_resume` and recompute their
    /// KV from position 0, so the streams they eventually produce are
    /// unchanged (pinned by tests/test_faults.rs).
    fn device_reset(&mut self, name: &str) -> Result<(), RuntimeError> {
        self.stats.device_resets += 1;
        let mut running = std::mem::take(&mut self.models.get_mut(name).unwrap().running);
        for seq in running.drain(..) {
            let m = self.models.get_mut(name).unwrap();
            match m.kv.get(seq.branch.seq_id) {
                Some(s) => {
                    let pre = PreemptedSeq {
                        tokens: s.tokens.clone(),
                        computed: s.written().min(s.len()),
                        sampled: true,
                        seq,
                    };
                    self.stats.preemptions += 1;
                    m.preempted.push_back(pre);
                }
                None => {
                    // No KV and no token history to recompute from:
                    // unrecoverable for this one request.
                    self.stats.requests_failed += 1;
                    Self::fail(&mut self.events, &mut self.families, m, seq, ApiError::internal(
                        "sequence lost its KV residency during device reset",
                    ));
                }
            }
        }
        let m = self.models.get_mut(name).unwrap();
        let prefilling = std::mem::take(&mut m.prefilling);
        for pf in prefilling {
            let computed = m.kv.get(pf.seq.branch.seq_id).map_or(0, |s| s.written());
            self.stats.preemptions += 1;
            m.preempted.push_back(PreemptedSeq {
                computed,
                sampled: pf.prefill_end < pf.prompt_ids.len(),
                tokens: pf.prompt_ids,
                seq: pf.seq,
            });
        }
        // Everything the pool knew — live residency, free pages, parked
        // prefix pages — described the lost device. Wipe, don't free.
        m.kv.invalidate_all();
        if let Some(d) = m.draft.as_mut() {
            d.kv.invalidate_all();
            d.backend.reset_cache()?;
        }
        m.backend.reset_cache()
    }

    /// Fail every resident request whose deadline has passed with a
    /// structured `timeout_error`. Runs before each model's scheduler
    /// step, so an expired request never consumes another model call.
    fn expire_deadlines(&mut self, name: &str) {
        let now = Instant::now();
        let default_ms = self.request_timeout_ms;
        let expired = |seq: &RunningSeq| {
            seq.branch.finish.is_none() && seq.deadline.map_or(false, |d| now >= d)
        };
        // Waiting requests never got a RunningSeq; derive their deadline.
        loop {
            let m = self.models.get_mut(name).unwrap();
            let hit = m.waiting.iter().position(|p| {
                deadline_at(p.t_admit, p.req.deadline_ms.or(default_ms))
                    .map_or(false, |d| now >= d)
            });
            match hit {
                Some(i) => {
                    let p = m.waiting.remove(i).expect("index in bounds");
                    self.stats.requests_timed_out += 1;
                    self.events.push_back(EngineEvent::Error(
                        p.req_id,
                        ApiError::timeout("request deadline passed before admission"),
                    ));
                }
                None => break,
            }
        }
        loop {
            let m = self.models.get_mut(name).unwrap();
            match m.running.iter().position(&expired) {
                Some(i) => {
                    let seq = m.running.remove(i);
                    self.stats.requests_timed_out += 1;
                    Self::fail(&mut self.events, &mut self.families, m, seq, ApiError::timeout(
                        "request deadline passed mid-decode",
                    ));
                }
                None => break,
            }
        }
        loop {
            let m = self.models.get_mut(name).unwrap();
            match m.prefilling.iter().position(|p| expired(&p.seq)) {
                Some(i) => {
                    let pf = m.prefilling.remove(i).expect("index in bounds");
                    self.stats.requests_timed_out += 1;
                    Self::fail(&mut self.events, &mut self.families, m, pf.seq, ApiError::timeout(
                        "request deadline passed mid-prefill",
                    ));
                }
                None => break,
            }
        }
        loop {
            let m = self.models.get_mut(name).unwrap();
            match m.preempted.iter().position(|p| expired(&p.seq)) {
                Some(i) => {
                    let p = m.preempted.remove(i).expect("index in bounds");
                    self.stats.requests_timed_out += 1;
                    Self::fail(&mut self.events, &mut self.families, m, p.seq, ApiError::timeout(
                        "request deadline passed while evicted",
                    ));
                }
                None => break,
            }
        }
    }

    /// Begin a graceful drain: admission stops immediately (`submit`
    /// returns 503), residents keep running. With `timeout_ms`, anything
    /// still unfinished that long from now is failed (`drain_failed`) so
    /// shutdown is bounded; without it the drain waits indefinitely.
    /// Idempotent — a second call can only tighten the deadline.
    pub fn drain(&mut self, timeout_ms: Option<u64>) {
        self.draining = true;
        if let Some(d) =
            timeout_ms.and_then(|ms| Instant::now().checked_add(Duration::from_millis(ms)))
        {
            let sooner = self.drain_deadline.map_or(true, |cur| d < cur);
            if sooner {
                self.drain_deadline = Some(d);
            }
        }
    }

    /// Whether [`Self::drain`] has been called.
    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// Drain complete: admission closed and no resident work remains.
    pub fn drained(&self) -> bool {
        self.draining && !self.has_work()
    }

    /// Past the drain deadline, fail whatever is still resident so the
    /// server's shutdown is bounded. Streams get a structured 503 error
    /// event (not a dropped connection mid-token).
    fn enforce_drain(&mut self, name: &str) {
        if !self.draining {
            return;
        }
        let Some(deadline) = self.drain_deadline else { return };
        if Instant::now() < deadline {
            return;
        }
        loop {
            let m = self.models.get_mut(name).unwrap();
            if let Some(p) = m.waiting.pop_front() {
                self.stats.drain_failed += 1;
                self.events.push_back(EngineEvent::Error(
                    p.req_id,
                    ApiError::unavailable("engine drained before this request ran"),
                ));
                continue;
            }
            if !m.running.is_empty() {
                let seq = m.running.remove(0);
                self.stats.drain_failed += 1;
                Self::fail(&mut self.events, &mut self.families, m, seq, ApiError::unavailable(
                    "drain deadline passed mid-decode",
                ));
                continue;
            }
            if let Some(pf) = m.prefilling.pop_front() {
                self.stats.drain_failed += 1;
                Self::fail(&mut self.events, &mut self.families, m, pf.seq, ApiError::unavailable(
                    "drain deadline passed mid-prefill",
                ));
                continue;
            }
            if let Some(p) = m.preempted.pop_front() {
                self.stats.drain_failed += 1;
                Self::fail(&mut self.events, &mut self.families, m, p.seq, ApiError::unavailable(
                    "drain deadline passed while evicted",
                ));
                continue;
            }
            break;
        }
    }

    fn step_model(&mut self, name: &str) -> Result<(), RuntimeError> {
        // Admission: prefill-prioritized (TTFT over throughput, the
        // interactive-first policy WebLLM wants in a UI) but not
        // exclusive — admitted prompts are fed in budget-sized chunks
        // alongside the decode batch below.
        self.admit_and_resume(name)?;
        self.prefill_chunk_step(name)?;
        self.decode_batch(name)
    }

    /// Importance order for scheduling and (inverted) victim selection:
    /// higher priority wins, ties go to the older request. Total —
    /// request ids are unique — so preemption can never cycle: `a` may
    /// evict `b` only when `more_important(a, b)`, a strict order.
    fn more_important(a: (i32, RequestId), b: (i32, RequestId)) -> bool {
        a.0 > b.0 || (a.0 == b.0 && a.1 < b.1)
    }

    /// Evict one sequence: pull it out of the decode batch or the
    /// prefill set, capture its token history, free its KV pages (fully
    /// written full pages park in the prefix cache, so the resume often
    /// restarts well past position 0), and queue it for re-admission.
    /// Sampler, grammar, and stream state ride along untouched — only
    /// KV residency is given up.
    fn preempt_at(&mut self, name: &str, from_running: bool, idx: usize) {
        let m = self.models.get_mut(name).unwrap();
        let pre = if from_running {
            let seq = m.running.remove(idx);
            let Some(s) = m.kv.get(seq.branch.seq_id) else {
                // No KV residency means no token history to recompute
                // from; fail this one request rather than the engine.
                self.stats.requests_failed += 1;
                Self::fail(&mut self.events, &mut self.families, m, seq, ApiError::internal(
                    "running sequence lost its KV residency",
                ));
                return;
            };
            PreemptedSeq {
                tokens: s.tokens.clone(),
                computed: s.written().min(s.len()),
                sampled: true,
                seq,
            }
        } else {
            let pf = m.prefilling.remove(idx).expect("index in bounds");
            let computed = m.kv.get(pf.seq.branch.seq_id).map_or(0, |s| s.written());
            PreemptedSeq {
                computed,
                // A resume evicted again keeps its sampled-ness through
                // the shortened prefill_end.
                sampled: pf.prefill_end < pf.prompt_ids.len(),
                tokens: pf.prompt_ids,
                seq: pf.seq,
            }
        };
        m.kv.free(pre.seq.branch.seq_id);
        if let Some(d) = m.draft.as_mut() {
            d.kv.free(pre.seq.branch.seq_id);
        }
        m.preempted.push_back(pre);
        self.stats.preemptions += 1;
    }

    /// The least important KV-holding sequence (decode batch + prefill
    /// set): the preemption victim. `beneficiary` restricts the pick to
    /// strictly less important sequences — an admission may only evict
    /// what it outranks; `None` (decode headroom) takes the global
    /// minimum. Returns `(from_running, index)`.
    fn pick_victim(
        &self,
        name: &str,
        beneficiary: Option<(i32, RequestId)>,
    ) -> Option<(bool, usize)> {
        let m = &self.models[name];
        let mut worst: Option<(bool, usize, (i32, RequestId))> = None;
        for (i, s) in m.running.iter().enumerate() {
            let key = (s.priority, s.req_id);
            if worst.map_or(true, |(_, _, w)| Self::more_important(w, key)) {
                worst = Some((true, i, key));
            }
        }
        for (i, p) in m.prefilling.iter().enumerate() {
            let key = (p.seq.priority, p.seq.req_id);
            if worst.map_or(true, |(_, _, w)| Self::more_important(w, key)) {
                worst = Some((false, i, key));
            }
        }
        let (from_running, idx, key) = worst?;
        match beneficiary {
            Some(b) if !Self::more_important(b, key) => None,
            _ => Some((from_running, idx)),
        }
    }

    /// Resume evicted victims and admit waiting requests, both in
    /// importance order, until the prefill slots, the decode batch, or
    /// the page pool say stop. A candidate that does not fit first tries
    /// to evict strictly-less-important victims (the priority-inversion
    /// guarantee: a high-priority submit waits at most one step behind
    /// low-priority KV holders); if even that fails, admission stops —
    /// head-of-line, so a large important prompt is never starved by
    /// small unimportant ones slipping past it.
    fn admit_and_resume(&mut self, name: &str) -> Result<(), RuntimeError> {
        // Aborted while evicted: pages are already free — just resolve.
        loop {
            let m = self.models.get_mut(name).unwrap();
            match m.preempted.iter().position(|p| p.seq.branch.finish.is_some()) {
                Some(i) => {
                    let p = m.preempted.remove(i).expect("index in bounds");
                    Self::finalize(
                        &mut self.events,
                        &mut self.stats,
                        &mut self.families,
                        m,
                        p.seq,
                        self.draining,
                    );
                }
                None => break,
            }
        }
        loop {
            let m = &self.models[name];
            if m.prefilling.len() >= self.max_concurrent_prefills
                || m.running.len() + m.prefilling.len() >= m.backend.config().max_decode_batch()
            {
                return Ok(());
            }
            let best_resume = {
                let mut best: Option<(usize, (i32, RequestId))> = None;
                for (i, p) in m.preempted.iter().enumerate() {
                    let key = (p.seq.priority, p.seq.req_id);
                    if best.map_or(true, |(_, b)| Self::more_important(key, b)) {
                        best = Some((i, key));
                    }
                }
                best
            };
            let best_admit = {
                let mut best: Option<(usize, (i32, RequestId))> = None;
                for (i, p) in m.waiting.iter().enumerate() {
                    let key = (p.req.priority, p.req_id);
                    if best.map_or(true, |(_, b)| Self::more_important(key, b)) {
                        best = Some((i, key));
                    }
                }
                best
            };
            // Joint importance order across both queues (ids are unique,
            // so there are no ties to break). `nb` is the fork fan-out a
            // fresh admission will need room for (a resumed victim is
            // one branch of its family and resumes alone).
            let (is_resume, idx, key, need, nb) = match (best_resume, best_admit) {
                (None, None) => return Ok(()),
                (Some((i, k)), None) => (true, i, k, m.preempted[i].tokens.len(), 1),
                (None, Some((i, k))) => {
                    (false, i, k, m.waiting[i].prompt_ids.len(), m.waiting[i].req.n)
                }
                (Some((ri, rk)), Some((ai, ak))) => {
                    if Self::more_important(ak, rk) {
                        (false, ai, ak, m.waiting[ai].prompt_ids.len(), m.waiting[ai].req.n)
                    } else {
                        (true, ri, rk, m.preempted[ri].tokens.len(), 1)
                    }
                }
            };
            // Make room: evict what the candidate outranks until it fits.
            while !self.models[name].kv.can_admit_family(need, nb) {
                match self.pick_victim(name, Some(key)) {
                    Some((fr, vi)) => self.preempt_at(name, fr, vi),
                    None => return Ok(()),
                }
            }
            if is_resume {
                self.resume_preempted(name, idx)?;
            } else {
                let m = self.models.get_mut(name).unwrap();
                let pending = m.waiting.remove(idx).expect("index in bounds");
                self.begin_prefill(name, pending)?;
            }
        }
    }

    /// Re-admit an evicted sequence: allocate fresh KV residency over its
    /// token history (prefix-cached pages — often its own, parked at
    /// eviction — shortcut the restart) and route it back through the
    /// prefill set to recompute the lost positions. A victim whose
    /// surviving prefix already covers everything rejoins the decode
    /// batch immediately.
    fn resume_preempted(&mut self, name: &str, idx: usize) -> Result<(), RuntimeError> {
        let m = self.models.get_mut(name).unwrap();
        let p = m.preempted.remove(idx).expect("index in bounds");
        let start = m
            .kv
            .admit(p.seq.branch.seq_id, &p.tokens)
            .map_err(|e| RuntimeError::Shape(format!("resume raced admission gate: {e}")))?
            .prefill_start();
        let prefill_end = if p.sampled { p.tokens.len() - 1 } else { p.tokens.len() };
        self.stats.preempted_tokens_recomputed +=
            p.computed.min(prefill_end).saturating_sub(start) as u64;
        if start >= prefill_end {
            // Every lost position survived in the prefix cache. Only
            // possible for sampled victims — a fresh prefill always has
            // at least the final prompt position left to compute.
            m.running.push(p.seq);
            return Ok(());
        }
        m.prefilling.push_back(PrefillingSeq {
            seq: p.seq,
            prompt_ids: p.tokens,
            next_pos: start,
            prefill_end,
        });
        Ok(())
    }

    /// Admit a pending request into the `Prefilling` state: allocate KV
    /// residency (reusing prefix-cached pages), compile/fetch the grammar,
    /// seed the sampler — but run no model compute yet. The first chunk
    /// starts at the prefix-cache boundary, so fully-cached leading pages
    /// cost nothing beyond this bookkeeping.
    fn begin_prefill(&mut self, name: &str, p: PendingReq) -> Result<(), RuntimeError> {
        let seq_id = self.next_seq;
        self.next_seq += 1;
        self.nonce = self.nonce.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let fallback_seed = self.nonce;

        // Compile-at-admission: the grammar's AOT vocabulary partition is
        // built (or fetched) here, once per distinct grammar — never on
        // the per-token path. The matcher is per-sequence state; the
        // `Rc<CompiledGrammar>` + mask cache are shared.
        let (matcher, mask_cache, forced_runs) = match &p.req.response_format {
            ResponseFormat::Text => (None, None, None),
            rf => {
                let entry = self.grammar_entry_for(rf);
                (Some(entry.compiled.matcher()), Some(entry.cache), Some(entry.runs))
            }
        };

        let start = {
            let m = self.models.get_mut(name).unwrap();
            let seq = m.kv.admit(seq_id, &p.prompt_ids).map_err(|e| {
                RuntimeError::Shape(format!("admission raced: {e}"))
            })?;
            seq.prefill_start()
        };
        self.stats.prefill_cached_tokens_skipped += start as u64;

        let max_ctx = {
            let m = &self.models[name];
            m.backend.config().max_seq_len - 1
        };
        let max_tokens = p.req.max_tokens.min(max_ctx.saturating_sub(p.prompt_ids.len()));

        let mut processor = LogitsProcessor::new(p.req.sampling.clone(), fallback_seed);
        for &t in &p.prompt_ids {
            processor.observe(t);
        }

        let seq = RunningSeq {
            req_id: p.req_id,
            model: name.to_string(),
            priority: p.req.priority,
            n_branches: p.req.n,
            sampling: p.req.sampling.clone(),
            fallback_seed,
            mask_cache,
            forced_runs,
            prompt_tokens: p.prompt_ids.len(),
            max_tokens,
            stop: p.req.stop.clone(),
            stream: p.req.stream,
            t_admit: p.t_admit,
            t_prefilled: None,
            deadline: deadline_at(p.t_admit, p.req.deadline_ms.or(self.request_timeout_ms)),
            accept_ewma: 1.0,
            branch: BranchState {
                index: 0,
                seq_id,
                processor,
                matcher,
                decoder: StreamDecoder::new(),
                text: String::new(),
                emitted: 0,
                completion_tokens: 0,
                logprobs: p.req.sampling.logprobs.then(Vec::new),
                finish: None,
                failed: None,
            },
        };
        let prefill_end = p.prompt_ids.len();
        self.models.get_mut(name).unwrap().prefilling.push_back(PrefillingSeq {
            seq,
            prompt_ids: p.prompt_ids,
            next_pos: start,
            prefill_end,
        });
        Ok(())
    }

    /// Run at most one positioned prefill chunk for the model's most
    /// important `Prefilling` sequence (round-robin within a priority
    /// class: the fed sequence rotates behind its peers). On a fresh
    /// admission's final chunk — whose logits are by construction the
    /// whole prompt's last-token logits — sample the first generated
    /// token and promote the sequence to the decode batch; a resumed
    /// preemption victim rejoins the batch directly once its lost
    /// positions are recomputed.
    fn prefill_chunk_step(&mut self, name: &str) -> Result<(), RuntimeError> {
        // Aborted mid-prefill: resolve without running further chunks.
        let mut resolved = false;
        loop {
            let m = self.models.get_mut(name).unwrap();
            match m.prefilling.iter().position(|pf| pf.seq.branch.finish.is_some()) {
                Some(i) => {
                    let pf = m.prefilling.remove(i).expect("index in bounds");
                    Self::finalize(
                        &mut self.events,
                        &mut self.stats,
                        &mut self.families,
                        m,
                        pf.seq,
                        self.draining,
                    );
                    resolved = true;
                }
                None => break,
            }
        }
        if resolved {
            return Ok(());
        }

        let (idx, done, n, chunk, t_chunk, stalled, logits) = {
            let m = self.models.get_mut(name).unwrap();
            if m.prefilling.is_empty() {
                return Ok(());
            }
            // Chunk allocation: the highest priority class present owns
            // the step; within it, the front-most (least recently fed).
            let top = m.prefilling.iter().map(|p| p.seq.priority).max().expect("non-empty");
            let idx = m
                .prefilling
                .iter()
                .position(|p| p.seq.priority == top)
                .expect("top came from this list");
            let budget = if self.adaptive_prefill {
                m.backend
                    .config()
                    .adaptive_prefill_budget(self.prefill_token_budget, m.running.len())
            } else {
                self.prefill_token_budget
            };
            let mc = m.backend.config();
            let pf = &mut m.prefilling[idx];
            let remaining = pf.prefill_end - pf.next_pos;
            let (n, chunk) = mc
                .next_prefill_tokens(remaining, budget)
                .expect("prefilling sequence always has remaining tokens");
            let mut ids = vec![0i32; chunk];
            for (i, &t) in pf.prompt_ids[pf.next_pos..pf.next_pos + n].iter().enumerate() {
                ids[i] = t as i32;
            }
            let bt = m.kv.block_table_row(pf.seq.branch.seq_id);
            Self::apply_pending_copies(&mut self.stats, m.backend.as_mut(), &mut m.kv)?;
            let t0 = Instant::now();
            let start_pos = pf.next_pos;
            let out = with_retries(&mut self.stats, || {
                m.backend.prefill_chunk(&ids, start_pos, n, &bt)
            })?;
            let t_chunk = t0.elapsed().as_secs_f64();
            pf.next_pos += n;
            // The chunk landed: its pages are now real KV, eligible for
            // prefix-cache registration when the sequence is freed.
            m.kv.note_written(pf.seq.branch.seq_id, pf.next_pos);
            let done = pf.next_pos == pf.prefill_end;
            (idx, done, n, chunk, t_chunk, !m.running.is_empty(), out.logits)
        };
        self.stats.prefill_tokens += n as u64;
        self.stats.prefill_padded_tokens += (chunk - n) as u64;
        self.stats.prefill_time_s += t_chunk;
        self.stats.prefill_chunks += 1;
        if stalled {
            // Decode rows existed and waited out this chunk: the
            // interference the chunk budget bounds.
            self.stats.decode_stall_s += t_chunk;
            self.stats.decode_stall_chunks += 1;
        }
        if !row_is_finite(&logits) {
            // Data-plane fault: the backend computed garbage for exactly
            // this sequence. Fail it with a structured error; every other
            // resident stream is untouched.
            self.stats.faults_injected += 1;
            self.stats.requests_failed += 1;
            let m = self.models.get_mut(name).unwrap();
            let pf = m.prefilling.remove(idx).expect("index in bounds");
            Self::fail(&mut self.events, &mut self.families, m, pf.seq, ApiError::data_plane(
                "non-finite logits row during prefill",
            ));
            return Ok(());
        }
        if !done {
            // Round-robin within the priority class: rotate the fed
            // sequence behind its peers.
            let m = self.models.get_mut(name).unwrap();
            let pf = m.prefilling.remove(idx).expect("index in bounds");
            m.prefilling.push_back(pf);
            return Ok(());
        }

        let mut pf = self
            .models
            .get_mut(name)
            .unwrap()
            .prefilling
            .remove(idx)
            .expect("index in bounds");
        if pf.prefill_end < pf.prompt_ids.len() {
            // Resumed victim: the KV it lost is recomputed, and its next
            // decode input was sampled before eviction — rejoin the
            // batch without sampling.
            self.models.get_mut(name).unwrap().running.push(pf.seq);
            return Ok(());
        }

        // Fan out `n>1` parallel sampling here, while the sequence is
        // exactly the prefilled prompt: the prompt was computed once, in
        // the chunks above, and every extra choice forks the parent's KV
        // pages — full written pages shared by refcount bump, only the
        // partially-filled tail page copied (or recomputed) — then gets
        // its own sampler, grammar matcher, and stream state.
        let siblings = match self.fork_family(name, &pf) {
            Ok(s) => s,
            Err(e) => {
                // Even eviction could not fund every branch's tail page:
                // fail the whole request rather than return fewer
                // choices than asked for.
                self.stats.requests_failed += 1;
                let m = self.models.get_mut(name).unwrap();
                Self::fail(&mut self.events, &mut self.families, m, pf.seq, e);
                return Ok(());
            }
        };

        pf.seq.t_prefilled = Some(Instant::now());
        self.stats.ttft.push(pf.seq.t_admit.elapsed().as_secs_f64());
        let t_prefilled = pf.seq.t_prefilled;
        let mut branches = Vec::with_capacity(1 + siblings.len());
        branches.push(pf.seq);
        branches.extend(siblings);

        // Sample each branch's first generated token from the final
        // chunk's logits — by construction the whole prompt's last-token
        // logits, identical for every branch. Samplers mutate the row in
        // place, so each branch works on its own copy.
        let mut logits = logits;
        let last = branches.len() - 1;
        let mut ff_err = None;
        for (i, mut seq) in branches.into_iter().enumerate() {
            seq.t_prefilled = t_prefilled;
            let mut row = if i < last { logits.clone() } else { std::mem::take(&mut logits) };
            self.consume_logits(&mut seq, &mut row);
            // The first token may open a grammar-forced run; take it
            // before the branch ever joins the decode batch.
            if seq.branch.finish.is_none() && ff_err.is_none() {
                ff_err = self.post_emit(&mut seq).err();
            }
            let m = self.models.get_mut(name).unwrap();
            if seq.branch.finish.is_some() {
                Self::finalize(
                    &mut self.events,
                    &mut self.stats,
                    &mut self.families,
                    m,
                    seq,
                    self.draining,
                );
            } else {
                m.running.push(seq);
            }
        }
        match ff_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Fork branches `1..n` of a freshly prefilled `n>1` request off its
    /// parent sequence. Each fork shares every full written page by
    /// refcount and takes a fresh tail page (queued for a physical copy
    /// when the backend supports it, recomputed by the flush path
    /// otherwise), so the family's prompt compute stays O(one prefill).
    /// Branch `i`'s sampler is rebuilt from the request's sampling
    /// template with the branch-mixed seed — byte-identical to an
    /// independent request carrying that seed. Page-pool pressure evicts
    /// strictly-less-important victims; if the pool still cannot fund a
    /// branch, everything forked so far is rolled back and the whole
    /// family fails.
    fn fork_family(
        &mut self,
        name: &str,
        pf: &PrefillingSeq,
    ) -> Result<Vec<RunningSeq>, ApiError> {
        let n = pf.seq.n_branches;
        let mut siblings: Vec<RunningSeq> = Vec::with_capacity(n.saturating_sub(1));
        if n <= 1 {
            return Ok(siblings);
        }
        let parent = pf.seq.branch.seq_id;
        let effective = pf.seq.sampling.seed.unwrap_or(pf.seq.fallback_seed);
        for i in 1..n {
            let child = self.next_seq;
            self.next_seq += 1;
            loop {
                let m = self.models.get_mut(name).unwrap();
                match m.kv.fork(parent, child) {
                    Ok(()) => break,
                    Err(AllocError::OutOfPages) => {
                        let key = (pf.seq.priority, pf.seq.req_id);
                        if let Some((fr, idx)) = self.pick_victim(name, Some(key)) {
                            self.preempt_at(name, fr, idx);
                            continue;
                        }
                        let m = self.models.get_mut(name).unwrap();
                        for s in &siblings {
                            m.kv.free(s.branch.seq_id);
                        }
                        return Err(ApiError::unavailable(format!(
                            "page pool cannot hold a {n}-way fork of this prompt"
                        )));
                    }
                    Err(AllocError::SeqLimit) => {
                        let m = self.models.get_mut(name).unwrap();
                        for s in &siblings {
                            m.kv.free(s.branch.seq_id);
                        }
                        return Err(ApiError::invalid(
                            "prompt too long to fork within the per-sequence page limit",
                        ));
                    }
                }
            }
            self.stats.forks += 1;
            let mut params = pf.seq.sampling.clone();
            params.seed = Some(branch_seed(effective, i));
            let mut processor = LogitsProcessor::new(params, pf.seq.fallback_seed);
            for &t in &pf.prompt_ids {
                processor.observe(t);
            }
            siblings.push(RunningSeq {
                req_id: pf.seq.req_id,
                model: pf.seq.model.clone(),
                priority: pf.seq.priority,
                n_branches: n,
                sampling: pf.seq.sampling.clone(),
                fallback_seed: pf.seq.fallback_seed,
                mask_cache: pf.seq.mask_cache.clone(),
                forced_runs: pf.seq.forced_runs.clone(),
                prompt_tokens: pf.seq.prompt_tokens,
                max_tokens: pf.seq.max_tokens,
                stop: pf.seq.stop.clone(),
                stream: pf.seq.stream,
                t_admit: pf.seq.t_admit,
                t_prefilled: pf.seq.t_prefilled,
                deadline: pf.seq.deadline,
                accept_ewma: 1.0,
                branch: BranchState {
                    index: i,
                    seq_id: child,
                    processor,
                    matcher: pf.seq.branch.matcher.clone(),
                    decoder: StreamDecoder::new(),
                    text: String::new(),
                    emitted: 0,
                    completion_tokens: 0,
                    logprobs: pf.seq.sampling.logprobs.then(Vec::new),
                    finish: None,
                    failed: None,
                },
            });
        }
        let shared = self.models[name].kv.shared_pages() as u64;
        if shared > self.stats.shared_pages {
            self.stats.shared_pages = shared;
        }
        self.families.insert(
            pf.seq.req_id,
            FamilyState {
                expected: n,
                resolved: 0,
                choices: (0..n).map(|_| None).collect(),
                error: None,
                usage: Usage::default(),
            },
        );
        Ok(siblings)
    }

    /// Make sure this step's decode appends can be served before the
    /// batch is built: when the page pool cannot cover every running
    /// row's next token, evict the least important KV-holding sequences
    /// (vLLM's recompute policy) until the rest fit. The most important
    /// sequence is never chosen while others remain, so it always makes
    /// progress and the engine cannot livelock; a lone sequence that
    /// still cannot grow is genuinely out of room and finishes with
    /// `Length` via the append failure it is about to hit.
    fn ensure_decode_headroom(&mut self, name: &str) {
        loop {
            let m = &self.models[name];
            if m.running.is_empty() {
                return;
            }
            let ps = m.backend.config().page_size;
            let need = m
                .running
                .iter()
                .filter(|seq| seq.branch.finish.is_none())
                .filter(|seq| {
                    m.kv
                        .get(seq.branch.seq_id)
                        .map_or(false, |s| s.len() / ps >= s.block_table.len())
                })
                .count();
            if need <= m.kv.available_pages() {
                return;
            }
            if m.running.len() + m.prefilling.len() <= 1 {
                return;
            }
            let Some((fr, idx)) = self.pick_victim(name, None) else {
                return;
            };
            self.preempt_at(name, fr, idx);
        }
    }

    fn decode_batch(&mut self, name: &str) -> Result<(), RuntimeError> {
        self.ensure_decode_headroom(name);
        if self.models[name].draft.is_some() {
            return self.spec_decode_batch(name);
        }
        let (rows, batch, logits, t_decode) = {
            let m = self.models.get_mut(name).unwrap();
            if m.running.is_empty() {
                return Ok(());
            }
            let mc = m.backend.config().clone();
            let live = m.running.len().min(mc.max_decode_batch());
            let batch = mc.pick_batch(live).expect("live <= max batch");
            let mp = mc.max_pages_per_seq();

            // Refill the persistent step buffers in place (no per-step
            // allocations; padding rows stay zeroed).
            m.step.reset(batch, mp);
            for (row, seq) in m.running.iter_mut().take(live).enumerate() {
                let Some(s) = m.kv.get(seq.branch.seq_id) else {
                    // Lost residency: leave the row as zeroed padding
                    // (the backend skips seq_len 0) and route the failure
                    // through the push-back loop below — never the batch.
                    seq.branch.failed = Some(ApiError::internal(
                        "running sequence lost its KV residency",
                    ));
                    continue;
                };
                let len = s.len();
                m.step.ids[row] = *s.tokens.last().unwrap() as i32;
                m.step.positions[row] = (len - 1) as i32;
                m.step.seq_lens[row] = len as i32;
                m.kv.write_block_table_row(
                    seq.branch.seq_id,
                    &mut m.step.tables[row * mp..row * mp + mp],
                );
            }
            Self::apply_pending_copies(&mut self.stats, m.backend.as_mut(), &mut m.kv)?;
            let t0 = Instant::now();
            let out = with_retries(&mut self.stats, || {
                m.backend.decode(
                    &m.step.ids,
                    &m.step.positions,
                    &m.step.seq_lens,
                    &m.step.tables,
                )
            })?;
            let t_decode = t0.elapsed().as_secs_f64();
            // Each live row's stepped token is now pool-resident.
            for (row, seq) in m.running.iter().take(live).enumerate() {
                if m.step.seq_lens[row] > 0 {
                    m.kv.note_written(seq.branch.seq_id, m.step.seq_lens[row] as usize);
                }
            }
            (live, batch, out.logits, t_decode)
        };
        self.stats.decode_time_s += t_decode;
        self.stats.decode_steps += 1;
        self.stats.decode_live_rows += rows as u64;
        self.stats.decode_padded_rows += (batch - rows) as u64;

        // Sample per live row, directly from the row's slice of the
        // returned [batch, vocab] logits — no per-row copy. Sequences are
        // moved out so `consume_logits` can borrow the engine mutably.
        let vocab = self.tokenizer.vocab_size();
        let mut running = std::mem::take(&mut self.models.get_mut(name).unwrap().running);
        let mut logits = logits;
        let mut first_err = None;
        for (row, seq) in running.iter_mut().take(rows).enumerate() {
            if seq.branch.finish.is_some() || seq.branch.failed.is_some() || first_err.is_some() {
                continue; // aborted, failed mid-build, or bailing on error
            }
            let row_logits = &mut logits[row * vocab..(row + 1) * vocab];
            if !row_is_finite(row_logits) {
                // Poisoned row: exactly this request fails; the other
                // rows of the same batch sample normally.
                self.stats.faults_injected += 1;
                seq.branch.failed = Some(ApiError::data_plane(
                    "non-finite logits row during decode",
                ));
                continue;
            }
            self.consume_logits(seq, row_logits);
            self.stats.decode_tokens += 1;
            self.stats.itl.push(t_decode / rows as f64);
            if seq.branch.finish.is_none() {
                if let Err(e) = self.post_emit(seq) {
                    first_err = Some(e);
                }
            }
        }

        let m = self.models.get_mut(name).unwrap();
        for mut seq in running {
            if let Some(e) = seq.branch.failed.take() {
                self.stats.requests_failed += 1;
                Self::fail(&mut self.events, &mut self.families, m, seq, e);
            } else if seq.branch.finish.is_some() {
                Self::finalize(
                    &mut self.events,
                    &mut self.stats,
                    &mut self.families,
                    m,
                    seq,
                    self.draining,
                );
            } else {
                m.running.push(seq);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// One speculative decode round per running sequence (instead of a
    /// row in the shared decode batch): the draft proposes a token run,
    /// the target verifies it in a single positioned call, and the
    /// request's own sampler decides every emitted token. Rows
    /// speculation can't serve fall back to [`Self::plain_decode_row`].
    fn spec_decode_batch(&mut self, name: &str) -> Result<(), RuntimeError> {
        if self.models[name].running.is_empty() {
            return Ok(());
        }
        let mut running = std::mem::take(&mut self.models.get_mut(name).unwrap().running);
        let mut first_err = None;
        for seq in running.iter_mut() {
            if seq.branch.finish.is_some() || seq.branch.failed.is_some() || first_err.is_some() {
                continue; // aborted, failed, or bailing out on error
            }
            if let Err(e) = self.spec_decode_row(name, seq) {
                first_err = Some(e);
            }
        }
        let m = self.models.get_mut(name).unwrap();
        for mut seq in running {
            if let Some(e) = seq.branch.failed.take() {
                self.stats.requests_failed += 1;
                Self::fail(&mut self.events, &mut self.families, m, seq, e);
            } else if seq.branch.finish.is_some() {
                Self::finalize(
                    &mut self.events,
                    &mut self.stats,
                    &mut self.families,
                    m,
                    seq,
                    self.draining,
                );
            } else {
                m.running.push(seq);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// One speculative round for one sequence. The draft proposes up to
    /// `spec_tokens` tokens; the target verifies `[last emitted token,
    /// proposals...]` as one positioned `verify_chunk` call whose row `i`
    /// is exactly the logits plain decode would have produced for
    /// position `i`; the request's sampler runs over each row in order,
    /// emitting until a sampled token disagrees with the proposal. The
    /// output stream is therefore token-for-token identical to plain
    /// decode — acceptance only controls how many tokens one model call
    /// yields. Rejected KV slots roll back via `note_written`: the pool
    /// slots stay physically dirty but unattended, and the next
    /// decode/verify rewrites them.
    fn spec_decode_row(&mut self, name: &str, seq: &mut RunningSeq) -> Result<(), RuntimeError> {
        if seq.branch.logprobs.is_some() {
            // Logprob reports need the plain path's per-token timing; the
            // verify rows would fold several report entries into one call.
            return self.plain_decode_row(name, seq);
        }
        if self.models[name].kv.get(seq.branch.seq_id).is_none() {
            // Lost residency: fail exactly this request via the batch
            // loop's push-back routing.
            seq.branch.failed = Some(ApiError::internal(
                "running sequence lost its KV residency",
            ));
            return Ok(());
        }
        // Proposal depth. Fixed at `--spec-tokens`, or — when adaptive —
        // scaled to this request's acceptance EWMA so low-accept rows
        // stop paying for draft tokens verification keeps discarding.
        // Output bytes never change either way: verification re-samples
        // every position, `k` only sizes the batch of candidates.
        let k = if self.adaptive_spec_tokens {
            let max = self.spec_tokens;
            (1 + (seq.accept_ewma * max.saturating_sub(1) as f64).round() as usize).min(max)
        } else {
            self.spec_tokens
        };
        let proposals = self.draft_propose(name, seq, k)?;
        if proposals.is_empty() {
            return self.plain_decode_row(name, seq);
        }

        let (base_len, want, logits, t_verify) = {
            let m = self.models.get_mut(name).unwrap();
            let mc = m.backend.config().clone();
            let Some(s) = m.kv.get(seq.branch.seq_id) else {
                seq.branch.failed = Some(ApiError::internal(
                    "running sequence lost its KV residency",
                ));
                return Ok(());
            };
            let len = s.len();
            let mut want = proposals.len();
            // Shrink the run rather than fail the row: every verified slot
            // needs a compiled chunk row and a resident page.
            while want > 0
                && (mc.pick_chunk(want + 1).is_none()
                    || m.kv.reserve(seq.branch.seq_id, len + want).is_err())
            {
                want -= 1;
            }
            if want == 0 {
                (len, 0, Vec::new(), 0.0)
            } else {
                let n = want + 1;
                let chunk = mc.pick_chunk(n).expect("checked above");
                let mut ids = vec![0i32; chunk];
                let s = m.kv.get(seq.branch.seq_id).expect("present: checked at row entry");
                ids[0] = *s.tokens.last().unwrap() as i32;
                for (i, &t) in proposals[..want].iter().enumerate() {
                    ids[i + 1] = t as i32;
                }
                let bt = m.kv.block_table_row(seq.branch.seq_id);
                Self::apply_pending_copies(&mut self.stats, m.backend.as_mut(), &mut m.kv)?;
                let t0 = Instant::now();
                let out = with_retries(&mut self.stats, || {
                    m.backend.verify_chunk(&ids, len - 1, n, &bt)
                })?;
                (len, want, out.logits, t0.elapsed().as_secs_f64())
            }
        };
        if want == 0 {
            return self.plain_decode_row(name, seq);
        }
        self.stats.decode_time_s += t_verify;
        self.stats.decode_steps += 1;
        self.stats.decode_live_rows += 1;
        self.stats.spec_steps += 1;
        self.stats.draft_proposed += want as u64;

        let vocab = self.tokenizer.vocab_size();
        let mut logits = logits;
        let mut accepted = 0usize;
        let mut emitted = 0usize;
        for i in 0..=want {
            if seq.branch.finish.is_some() {
                break;
            }
            let row = &mut logits[i * vocab..(i + 1) * vocab];
            if !row_is_finite(row) {
                // Poisoned verify row: everything emitted so far from the
                // finite prefix stands; the request fails here.
                self.stats.faults_injected += 1;
                seq.branch.failed = Some(ApiError::data_plane(
                    "non-finite logits row during speculative verify",
                ));
                break;
            }
            let token = self.sample_token(seq, row);
            self.stats.decode_tokens += 1;
            emitted += 1;
            let matched = i < want && token == proposals[i];
            self.emit_token(seq, token);
            if !matched {
                break;
            }
            accepted += 1;
            self.stats.draft_accepted += 1;
        }
        {
            // Roll written-ness back to the accepted prefix (plus the slot
            // row 0 rewrote). Clamped: the final emission may have failed
            // to append.
            let m = self.models.get_mut(name).unwrap();
            let len_now = m.kv.get(seq.branch.seq_id).map(|s| s.len());
            if let Some(len_now) = len_now {
                m.kv.note_written(seq.branch.seq_id, (base_len + accepted).min(len_now));
            }
        }
        if emitted > 0 {
            let per = t_verify / emitted as f64;
            for _ in 0..emitted {
                self.stats.itl.push(per);
            }
        }
        // Fold this round's acceptance into the request's EWMA (starts
        // optimistic at 1.0, so fully-accepting streams never shrink).
        seq.accept_ewma = 0.7 * seq.accept_ewma + 0.3 * (accepted as f64 / want as f64);
        if seq.branch.finish.is_none() && seq.branch.failed.is_none() {
            self.post_emit(seq)?;
        }
        Ok(())
    }

    /// Single-sequence decode step outside the shared batch: the fallback
    /// for rows speculation can't serve (logprob reports, empty draft
    /// proposals, an exhausted page pool).
    fn plain_decode_row(&mut self, name: &str, seq: &mut RunningSeq) -> Result<(), RuntimeError> {
        let (batch, logits, t_decode) = {
            let m = self.models.get_mut(name).unwrap();
            let mc = m.backend.config().clone();
            let batch = mc.pick_batch(1).expect("decode menu is non-empty");
            let mp = mc.max_pages_per_seq();
            m.step.reset(batch, mp);
            let Some(s) = m.kv.get(seq.branch.seq_id) else {
                seq.branch.failed = Some(ApiError::internal(
                    "running sequence lost its KV residency",
                ));
                return Ok(());
            };
            let len = s.len();
            m.step.ids[0] = *s.tokens.last().unwrap() as i32;
            m.step.positions[0] = (len - 1) as i32;
            m.step.seq_lens[0] = len as i32;
            m.kv.write_block_table_row(seq.branch.seq_id, &mut m.step.tables[..mp]);
            Self::apply_pending_copies(&mut self.stats, m.backend.as_mut(), &mut m.kv)?;
            let t0 = Instant::now();
            let out = with_retries(&mut self.stats, || {
                m.backend.decode(
                    &m.step.ids,
                    &m.step.positions,
                    &m.step.seq_lens,
                    &m.step.tables,
                )
            })?;
            let t_decode = t0.elapsed().as_secs_f64();
            m.kv.note_written(seq.branch.seq_id, len);
            (batch, out.logits, t_decode)
        };
        self.stats.decode_time_s += t_decode;
        self.stats.decode_steps += 1;
        self.stats.decode_live_rows += 1;
        self.stats.decode_padded_rows += (batch - 1) as u64;
        let vocab = self.tokenizer.vocab_size();
        let mut logits = logits;
        if !row_is_finite(&logits[..vocab]) {
            self.stats.faults_injected += 1;
            seq.branch.failed = Some(ApiError::data_plane(
                "non-finite logits row during decode",
            ));
            return Ok(());
        }
        self.consume_logits(seq, &mut logits[..vocab]);
        self.stats.decode_tokens += 1;
        self.stats.itl.push(t_decode);
        if seq.branch.finish.is_none() && seq.branch.failed.is_none() {
            self.post_emit(seq)?;
        }
        Ok(())
    }

    /// Run the draft ahead of the target: mirror the target's token state
    /// into the draft's own KV manager (truncating whatever a past
    /// rejection left behind), then decode up to `k` proposals
    /// autoregressively. Grammar-constrained requests constrain the draft
    /// too — a proposal the mask bans could never survive verification.
    fn draft_propose(
        &mut self,
        name: &str,
        seq: &mut RunningSeq,
        k: usize,
    ) -> Result<Vec<u32>, RuntimeError> {
        let tokenizer = self.tokenizer.clone();
        let eos = self.eos_ids.clone();
        let temperature = seq.branch.processor.params().temperature;
        let m = self.models.get_mut(name).unwrap();
        let Some(d) = m.draft.as_mut() else {
            return Ok(Vec::new());
        };
        let target_tokens = match m.kv.get(seq.branch.seq_id) {
            Some(s) => s.tokens.clone(),
            None => return Ok(Vec::new()),
        };

        // Sync the mirror: roll back past any rejected suffix, then append
        // what the target emitted since the last round. Failures here are
        // soft — an empty proposal list falls back to plain decode.
        if d.kv.get(seq.branch.seq_id).is_none() {
            if d.kv.admit(seq.branch.seq_id, &target_tokens).is_err() {
                return Ok(Vec::new());
            }
        } else {
            let common = d
                .kv
                .get(seq.branch.seq_id)
                .unwrap()
                .tokens
                .iter()
                .zip(&target_tokens)
                .take_while(|(a, b)| a == b)
                .count();
            d.kv.truncate(seq.branch.seq_id, common);
            for &t in &target_tokens[common..] {
                if d.kv.append_token(seq.branch.seq_id, t).is_err() {
                    return Ok(Vec::new());
                }
            }
        }
        Self::flush_unwritten_kv(
            &mut self.stats,
            d.backend.as_mut(),
            &mut d.kv,
            seq.branch.seq_id,
        )?;

        let mc = d.backend.config().clone();
        let Some(batch) = mc.pick_batch(1) else {
            return Ok(Vec::new());
        };
        let mp = mc.max_pages_per_seq();
        let mut ids = vec![0i32; batch];
        let mut positions = vec![0i32; batch];
        let mut seq_lens = vec![0i32; batch];
        let mut tables = vec![0i32; batch * mp];
        // The draft's grammar shadow: advanced per proposal, discarded at
        // the end of the round (the real matcher advances in emit_token).
        let mut shadow = seq.branch.matcher.clone();
        let mut proposals = Vec::new();
        while proposals.len() < k {
            let s = d.kv.get(seq.branch.seq_id).expect("mirror admitted above");
            let len = s.len();
            if len + 1 >= mc.max_seq_len {
                break;
            }
            ids[0] = *s.tokens.last().unwrap() as i32;
            positions[0] = (len - 1) as i32;
            seq_lens[0] = len as i32;
            d.kv.write_block_table_row(seq.branch.seq_id, &mut tables[..mp]);
            let out = d.backend.decode(&ids, &positions, &seq_lens, &tables)?;
            d.kv.note_written(seq.branch.seq_id, len);
            let mask_rc: Rc<TokenBitmask>;
            let mask = match (&shadow, &seq.mask_cache) {
                (Some(matcher), Some(cache)) => {
                    mask_rc = cache.borrow_mut().get_or_compute(matcher);
                    Some(&*mask_rc)
                }
                _ => None,
            };
            let pick =
                draft_pick(temperature, &mut d.rng, &out.logits[..mc.vocab_size], mask, &eos);
            let Some(tok) = pick else {
                break;
            };
            if let Some(matcher) = shadow.as_mut() {
                if !matcher.accept_token(tokenizer.token_bytes(tok)) {
                    break;
                }
            }
            if d.kv.append_token(seq.branch.seq_id, tok).is_err() {
                break;
            }
            proposals.push(tok);
        }
        Ok(proposals)
    }

    /// Everything that should follow an emitted token outside the model
    /// call itself: fast-forward any grammar-forced run, then compute KV
    /// for appended-but-unwritten positions so the next step's attention
    /// sees them.
    fn post_emit(&mut self, seq: &mut RunningSeq) -> Result<(), RuntimeError> {
        self.fast_forward(seq);
        if seq.branch.finish.is_some() {
            // finalize() frees the pages, and unwritten tails are never
            // registered for prefix reuse — nothing to flush.
            return Ok(());
        }
        let m = self.models.get_mut(&seq.model).unwrap();
        Self::flush_unwritten_kv(&mut self.stats, m.backend.as_mut(), &mut m.kv, seq.branch.seq_id)
    }

    /// Grammar fast-forward: while the matcher sits in non-accepting
    /// states whose masks allow exactly one token, emit that run directly
    /// — zero model calls, zero sampler draws. Runs are memoized per
    /// start state in the grammar's shared forced-run cache, so a literal
    /// spanning k tokens costs one lookup after first sight. Greedy
    /// decoding is unchanged token-for-token; sampled requests skip only
    /// the deterministic single-candidate draws. Logprob reports need a
    /// distribution per token, so those requests opt out.
    fn fast_forward(&mut self, seq: &mut RunningSeq) {
        if !self.enable_fast_forward
            || seq.branch.logprobs.is_some()
            || seq.branch.finish.is_some()
        {
            return;
        }
        let (cache, runs) = match (&seq.mask_cache, &seq.forced_runs) {
            (Some(c), Some(r)) => (c.clone(), r.clone()),
            _ => return,
        };
        let compiled = cache.borrow().compiled().clone();
        if !compiled.ff_possible() {
            return;
        }
        loop {
            let matcher = seq.branch.matcher.as_ref().expect("mask cache implies matcher");
            if matcher.is_accepting() {
                return;
            }
            let fp = matcher.fingerprint();
            let cached = runs.borrow_mut().get(&fp).cloned();
            let run = match cached {
                Some(run) => run,
                None => {
                    let computed =
                        Rc::new(Self::forced_run(&compiled, &cache, matcher, &self.tokenizer));
                    runs.borrow_mut().insert(fp, computed.clone());
                    computed
                }
            };
            if run.is_empty() {
                return;
            }
            let chained = run.len() == MAX_FF_RUN;
            for &tok in run.iter() {
                if seq.branch.finish.is_some() {
                    return;
                }
                // The sampler never sees forced tokens; keep its penalty
                // state in sync by hand.
                seq.branch.processor.observe(tok);
                self.stats.ff_tokens += 1;
                self.emit_token(seq, tok);
            }
            if !chained || seq.branch.finish.is_some() {
                return;
            }
        }
    }

    /// Chase the forced-state chain from `matcher`'s state: the longest
    /// run of single-token masks, capped at [`MAX_FF_RUN`] tokens.
    /// Exactly-compiled grammars answer each link from the AOT per-state
    /// table; inexact compiles fall back to the mask cache.
    fn forced_run(
        compiled: &CompiledGrammar,
        cache: &Rc<RefCell<MaskCache>>,
        matcher: &GrammarMatcher,
        tokenizer: &Tokenizer,
    ) -> Vec<u32> {
        let mut probe = matcher.clone();
        let mut run = Vec::new();
        while run.len() < MAX_FF_RUN && !probe.is_accepting() {
            let tok = match compiled.forced_token(&probe) {
                Some(Some(t)) => t,
                Some(None) => break,
                None => {
                    let mask = cache.borrow_mut().get_or_compute(&probe);
                    if mask.count_allowed() != 1 {
                        break;
                    }
                    mask.iter_allowed().next().expect("count checked") as u32
                }
            };
            if !probe.accept_token(tokenizer.token_bytes(tok)) {
                break;
            }
            run.push(tok);
        }
        run
    }

    /// Drain the KV manager's queued copy-on-write page copies into the
    /// backend. Forks and CoW un-shares only redirect page-table entries
    /// and queue `(src, dst)` pairs; the physical KV moves happen here,
    /// immediately before the next model call reads or writes those
    /// pages. Backends without `copy_page` never queue (the manager
    /// clamps `written` instead and the flush path recomputes), so this
    /// is a no-op for them.
    fn apply_pending_copies(
        stats: &mut EngineStats,
        backend: &mut dyn ModelBackend,
        kv: &mut KvCacheManager,
    ) -> Result<(), RuntimeError> {
        for (src, dst) in kv.take_pending_copies() {
            with_retries(stats, || backend.copy_page(src, dst))?;
            stats.cow_page_copies += 1;
        }
        Ok(())
    }

    /// Compute KV for a sequence's appended-but-unwritten positions
    /// `[written, len - 1)` as positioned prefill chunks; the final
    /// position is the next decode/verify call's input and writes
    /// itself. Serves both the target and the draft mirror. Deliberately
    /// not counted in the prefill stats — these are decode-side catch-up
    /// writes, not prompt work.
    fn flush_unwritten_kv(
        stats: &mut EngineStats,
        backend: &mut dyn ModelBackend,
        kv: &mut KvCacheManager,
        seq_id: u64,
    ) -> Result<(), RuntimeError> {
        Self::apply_pending_copies(stats, backend, kv)?;
        let (len, mut pos) = match kv.get(seq_id) {
            Some(s) => (s.len(), s.written()),
            None => return Ok(()),
        };
        if len == 0 {
            return Ok(());
        }
        let mc = backend.config().clone();
        while pos < len - 1 {
            let (n, chunk) = mc
                .next_prefill_tokens(len - 1 - pos, usize::MAX)
                .expect("remaining > 0");
            let mut ids = vec![0i32; chunk];
            let s = kv.get(seq_id).expect("checked above");
            for (i, &t) in s.tokens[pos..pos + n].iter().enumerate() {
                ids[i] = t as i32;
            }
            let bt = kv.block_table_row(seq_id);
            with_retries(stats, || backend.prefill_chunk(&ids, pos, n, &bt))?;
            pos += n;
            kv.note_written(seq_id, pos);
        }
        Ok(())
    }

    /// Sample one token from `logits` under the sequence's grammar mask,
    /// recording the logprob report entry when requested. Shared by the
    /// plain decode path and every speculative verify row.
    fn sample_token(&mut self, seq: &mut RunningSeq, logits: &mut [f32]) -> u32 {
        // Grammar mask straight from the cache — an Rc clone, O(1) even at
        // 128k vocab. The EOS allowance (legal once the derivation is
        // complete) rides along as `allow_extra` instead of copying the
        // mask to flip bits on it.
        let mask_rc: Rc<TokenBitmask>;
        let mut extra: &[u32] = &[];
        let mask: Option<&TokenBitmask> = match (&seq.branch.matcher, &seq.mask_cache) {
            (Some(matcher), Some(cache)) => {
                mask_rc = cache.borrow_mut().get_or_compute(matcher);
                if matcher.is_accepting() {
                    extra = &self.eos_ids;
                }
                Some(&mask_rc)
            }
            _ => None,
        };

        let (token, lp) =
            seq.branch.processor
                .sample_with_logprobs_masked_with(&mut self.scratch, logits, mask, extra);
        if let (Some(list), Some(lp)) = (&mut seq.branch.logprobs, lp) {
            let tok_str = |t: u32| {
                String::from_utf8_lossy(self.tokenizer.token_bytes(t)).into_owned()
            };
            list.push(LogprobEntry {
                token: tok_str(lp.token),
                logprob: lp.logprob as f64,
                top: lp.top.iter().map(|&(t, l)| (tok_str(t), l as f64)).collect(),
            });
        }
        token
    }

    /// Sample one token from `logits`, append it, detokenize, stream, and
    /// update finish state. Shared by the prefill (first token) and decode
    /// paths.
    fn consume_logits(&mut self, seq: &mut RunningSeq, logits: &mut [f32]) {
        let token = self.sample_token(seq, logits);
        self.emit_token(seq, token);
    }

    /// Every post-sample side effect of emitting `token`: grammar
    /// advance, KV append, detokenization, stop handling, streaming.
    /// Fast-forwarded and speculative tokens share this path with plain
    /// decode, so finish semantics can't drift between them.
    fn emit_token(&mut self, seq: &mut RunningSeq, token: u32) {
        // EOS / special tokens never enter the text.
        if self.eos_ids.contains(&token) {
            seq.branch.finish = Some(FinishReason::Stop);
            return;
        }

        // Advance the grammar.
        if let Some(matcher) = &mut seq.branch.matcher {
            let ok = matcher.accept_token(self.tokenizer.token_bytes(token));
            if !ok {
                // Fallback-path token (fully-masked state): end the output.
                seq.branch.finish = Some(FinishReason::Stop);
                return;
            }
        }

        // Bookkeeping in the KV manager. Hitting the per-sequence cap is
        // out of context (finish with Length, vLLM-style); pool
        // exhaustion is recoverable — evict something this sequence
        // outranks and retry the append.
        loop {
            let m = self.models.get_mut(&seq.model).unwrap();
            match m.kv.append_token(seq.branch.seq_id, token) {
                Ok(()) => break,
                Err(AllocError::SeqLimit) => {
                    seq.branch.finish = Some(FinishReason::Length);
                    return;
                }
                Err(AllocError::OutOfPages) => {
                    let model = seq.model.clone();
                    match self.pick_victim(&model, Some((seq.priority, seq.req_id))) {
                        Some((fr, idx)) => self.preempt_at(&model, fr, idx),
                        None => {
                            seq.branch.finish = Some(FinishReason::Length);
                            return;
                        }
                    }
                }
            }
        }
        seq.branch.completion_tokens += 1;

        // Detokenize incrementally (WASM CPU stage in browser mode).
        let bytes = self.tokenizer.token_bytes(token);
        let piece = match &self.env {
            Some(env) => env.cpu_stage(|| seq.branch.decoder.push(bytes)),
            None => seq.branch.decoder.push(bytes),
        };
        seq.branch.text.push_str(&piece);

        // Stop strings with holdback.
        let max_stop = seq.stop.iter().map(String::len).max().unwrap_or(0);
        if max_stop > 0 {
            let scan_from = seq.branch.emitted.saturating_sub(max_stop);
            if let Some((at, _)) = seq
                .stop
                .iter()
                .filter_map(|s| {
                    seq.branch.text[scan_from..].find(s.as_str()).map(|i| (scan_from + i, s))
                })
                .min_by_key(|(i, _)| *i)
            {
                seq.branch.text.truncate(at);
                seq.branch.finish = Some(FinishReason::Stop);
                return;
            }
        }

        if seq.branch.completion_tokens >= seq.max_tokens {
            seq.branch.finish = Some(FinishReason::Length);
        }

        // Grammar complete and nothing more derivable => stop.
        if let Some(matcher) = &seq.branch.matcher {
            if matcher.is_accepting() && matcher.is_dead() {
                seq.branch.finish = Some(FinishReason::Stop);
            }
        }

        // Stream the safe region (hold back potential stop-string prefixes).
        if seq.stream && seq.branch.finish.is_none() {
            let safe_end = seq.branch.text.len().saturating_sub(max_stop.saturating_sub(1));
            if safe_end > seq.branch.emitted && seq.branch.text.is_char_boundary(safe_end) {
                let delta = seq.branch.text[seq.branch.emitted..safe_end].to_string();
                seq.branch.emitted = safe_end;
                self.events.push_back(EngineEvent::Chunk(
                    seq.req_id,
                    ChatChunk {
                        id: format!("chatcmpl-{}", seq.req_id),
                        model: seq.model.clone(),
                        index: seq.branch.index,
                        delta,
                        finish_reason: None,
                        usage: None,
                    },
                ));
            }
        }
    }

    /// Terminate `seq` with a structured error instead of a completion:
    /// free its (and any draft mirror's) KV residency and emit an
    /// `Error` event. The caller owns the counter bump — timeout, drain,
    /// and data-plane failures each count in their own bucket. A branch
    /// of a forked family records the first error and stays silent until
    /// every sibling has resolved (each must free its pages through this
    /// path or [`Self::finalize`]); the request then emits exactly one
    /// `Error`, discarding any partial choices.
    fn fail(
        events: &mut VecDeque<EngineEvent>,
        families: &mut BTreeMap<RequestId, FamilyState>,
        m: &mut EngineModel,
        seq: RunningSeq,
        error: ApiError,
    ) {
        m.kv.free(seq.branch.seq_id);
        if let Some(d) = m.draft.as_mut() {
            d.kv.free(seq.branch.seq_id);
        }
        if let Some(fam) = families.get_mut(&seq.req_id) {
            if fam.error.is_none() {
                fam.error = Some(error);
            }
            fam.resolved += 1;
            if fam.resolved == fam.expected {
                let fam = families.remove(&seq.req_id).expect("entry just seen");
                events.push_back(EngineEvent::Error(
                    seq.req_id,
                    fam.error.expect("set above"),
                ));
            }
            return;
        }
        events.push_back(EngineEvent::Error(seq.req_id, error));
    }

    /// Complete one finished branch. For `n=1` that is the whole
    /// request: stream the trailing chunks and emit `Done`. A branch of
    /// a forked family instead parks its `Choice` in the family slot
    /// (and streams its own trailing chunks, tagged with its index); the
    /// single `Done` — index-ordered choices, aggregate usage — goes out
    /// when the last sibling lands. Per-request counters (`e2e`,
    /// `drain_completed`) bump once per family, not once per branch.
    fn finalize(
        events: &mut VecDeque<EngineEvent>,
        stats: &mut EngineStats,
        families: &mut BTreeMap<RequestId, FamilyState>,
        m: &mut EngineModel,
        mut seq: RunningSeq,
        draining: bool,
    ) {
        m.kv.free(seq.branch.seq_id);
        if let Some(d) = m.draft.as_mut() {
            d.kv.free(seq.branch.seq_id);
        }
        seq.branch.text.push_str(&seq.branch.decoder.finish());
        // The final flush may surface held-back bytes; the contract is
        // that a stop string never appears in the returned text.
        if let Some(at) = seq
            .stop
            .iter()
            .filter_map(|s| seq.branch.text.find(s.as_str()))
            .min()
        {
            seq.branch.text.truncate(at);
            seq.branch.finish = Some(FinishReason::Stop);
        }
        let finish = seq.branch.finish.unwrap_or(FinishReason::Stop);
        let e2e = seq.t_admit.elapsed().as_secs_f64();
        let ttft = seq
            .t_prefilled
            .map(|t| e2e - t.elapsed().as_secs_f64())
            .unwrap_or(e2e);
        let decode_s = (e2e - ttft).max(1e-9);
        let usage = Usage {
            prompt_tokens: seq.prompt_tokens,
            completion_tokens: seq.branch.completion_tokens,
            prefill_tokens_per_s: seq.prompt_tokens as f64 / ttft.max(1e-9),
            decode_tokens_per_s: seq.branch.completion_tokens as f64 / decode_s,
            ttft_s: ttft,
            e2e_s: e2e,
        };

        if let Some(fam) = families.get_mut(&seq.req_id) {
            // Aggregate usage: the prompt was prefilled once for the
            // whole family, completions sum, wall-clock is the slowest
            // branch. Rates are recomputed from the aggregate once the
            // family completes.
            fam.usage.prompt_tokens = usage.prompt_tokens;
            fam.usage.completion_tokens += usage.completion_tokens;
            fam.usage.ttft_s = fam.usage.ttft_s.max(usage.ttft_s);
            fam.usage.e2e_s = fam.usage.e2e_s.max(usage.e2e_s);
            fam.resolved += 1;
            let done = fam.resolved == fam.expected;
            if done {
                fam.usage.prefill_tokens_per_s =
                    fam.usage.prompt_tokens as f64 / fam.usage.ttft_s.max(1e-9);
                fam.usage.decode_tokens_per_s = fam.usage.completion_tokens as f64
                    / (fam.usage.e2e_s - fam.usage.ttft_s).max(1e-9);
            }
            if seq.stream {
                if seq.branch.text.len() > seq.branch.emitted {
                    events.push_back(EngineEvent::Chunk(
                        seq.req_id,
                        ChatChunk {
                            id: format!("chatcmpl-{}", seq.req_id),
                            model: seq.model.clone(),
                            index: seq.branch.index,
                            delta: seq.branch.text[seq.branch.emitted..].to_string(),
                            finish_reason: None,
                            usage: None,
                        },
                    ));
                }
                events.push_back(EngineEvent::Chunk(
                    seq.req_id,
                    ChatChunk {
                        id: format!("chatcmpl-{}", seq.req_id),
                        model: seq.model.clone(),
                        index: seq.branch.index,
                        delta: String::new(),
                        finish_reason: Some(finish),
                        // The aggregate rides the last branch to land.
                        usage: done.then(|| fam.usage.clone()),
                    },
                ));
            }
            fam.choices[seq.branch.index] = Some(Choice {
                index: seq.branch.index,
                content: seq.branch.text,
                finish_reason: finish,
                logprobs: seq.branch.logprobs,
            });
            if done {
                if draining {
                    stats.drain_completed += 1;
                }
                if fam.error.is_none() {
                    stats.e2e.push(fam.usage.e2e_s);
                }
                let fam = families.remove(&seq.req_id).expect("entry just seen");
                match fam.error {
                    Some(e) => events.push_back(EngineEvent::Error(seq.req_id, e)),
                    None => events.push_back(EngineEvent::Done(
                        seq.req_id,
                        ChatCompletionResponse {
                            id: format!("chatcmpl-{}", seq.req_id),
                            model: seq.model.clone(),
                            created: std::time::SystemTime::now()
                                .duration_since(std::time::UNIX_EPOCH)
                                .map(|d| d.as_secs())
                                .unwrap_or(0),
                            choices: fam.choices.into_iter().flatten().collect(),
                            usage: fam.usage,
                        },
                    )),
                }
            }
            return;
        }

        if draining {
            stats.drain_completed += 1;
        }
        stats.e2e.push(e2e);
        if seq.stream {
            // Trailing un-emitted text, then the final chunk.
            if seq.branch.text.len() > seq.branch.emitted {
                events.push_back(EngineEvent::Chunk(
                    seq.req_id,
                    ChatChunk {
                        id: format!("chatcmpl-{}", seq.req_id),
                        model: seq.model.clone(),
                        index: seq.branch.index,
                        delta: seq.branch.text[seq.branch.emitted..].to_string(),
                        finish_reason: None,
                        usage: None,
                    },
                ));
            }
            events.push_back(EngineEvent::Chunk(
                seq.req_id,
                ChatChunk {
                    id: format!("chatcmpl-{}", seq.req_id),
                    model: seq.model.clone(),
                    index: seq.branch.index,
                    delta: String::new(),
                    finish_reason: Some(finish),
                    usage: Some(usage.clone()),
                },
            ));
        }
        events.push_back(EngineEvent::Done(
            seq.req_id,
            ChatCompletionResponse {
                id: format!("chatcmpl-{}", seq.req_id),
                model: seq.model.clone(),
                created: std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_secs())
                    .unwrap_or(0),
                choices: vec![Choice {
                    index: seq.branch.index,
                    content: seq.branch.text,
                    finish_reason: finish,
                    logprobs: seq.branch.logprobs,
                }],
                usage,
            },
        ));
    }

    /// Parse/compile the request's grammar *source* into the byte-level
    /// CFG (submit calls this for synchronous validation; admission calls
    /// it again and hands the result to the AOT compiler).
    fn build_grammar(&self, rf: &ResponseFormat) -> Result<Option<Grammar>, ApiError> {
        let grammar: Option<Grammar> = match rf {
            ResponseFormat::Text => None,
            ResponseFormat::JsonObject => Some(
                schema_to_grammar(&Value::object())
                    .map_err(|e| ApiError::invalid(e.to_string()))?,
            ),
            ResponseFormat::JsonSchema(s) => {
                Some(schema_to_grammar(s).map_err(|e| ApiError::invalid(e.to_string()))?)
            }
            ResponseFormat::Grammar(text) => {
                let build = || parse_ebnf(text);
                let g = match &self.env {
                    Some(env) => env.cpu_stage(build),
                    None => build(),
                }
                .map_err(|e| ApiError::invalid(e.to_string()))?;
                Some(g)
            }
        };
        Ok(grammar)
    }

    /// The shared `CompiledGrammar` + LRU mask cache for this response
    /// format, compiling on first sight (a hit skips even the CFG
    /// rebuild). On a miss the finished CFG from the EBNF/JSON-Schema
    /// frontends is handed to `grammar::compiler` here, together with
    /// the engine's vocabulary trie.
    fn grammar_entry_for(&mut self, rf: &ResponseFormat) -> GrammarEntry {
        let key = match rf {
            ResponseFormat::Text => unreachable!("no cache for free text"),
            ResponseFormat::JsonObject => "json_object".to_string(),
            ResponseFormat::JsonSchema(s) => format!("schema:{}", crate::json::to_string(s)),
            ResponseFormat::Grammar(g) => format!("ebnf:{g}"),
        };
        if let Some(entry) = self.grammar_caches.get(&key) {
            return entry.clone();
        }
        let grammar = self
            .build_grammar(rf)
            .expect("validated at submit")
            .expect("non-text response format");
        let tokenizer = self.tokenizer.clone();
        let compiled = Rc::new(CompiledGrammar::compile(Rc::new(grammar), &self.trie, |i| {
            tokenizer.token_bytes(i)
        }));
        self.stats.grammar_compiles += 1;
        self.stats.grammar_compile_s += compiled.compile_seconds();
        self.stats.grammar_base_accept_tokens += compiled.base_accept().count_allowed() as u64;
        self.stats.grammar_base_reject_tokens += compiled.base_reject().count_allowed() as u64;
        self.stats.grammar_residue_tokens += compiled.residue().len() as u64;
        // Seeded from the compile pass's per-state masks: states the AOT
        // exploration already solved never score a runtime miss.
        let cache = Rc::new(RefCell::new(MaskCache::seeded(
            compiled.clone(),
            self.mask_cache_capacity,
        )));
        let runs = Rc::new(RefCell::new(LruMap::new(FORCED_RUN_CACHE_CAPACITY)));
        let entry = GrammarEntry { compiled, cache, runs };
        if let Some((_, evicted)) = self.grammar_caches.insert(key, entry.clone()) {
            // Absorb the victim's counters so stats_json stays monotonic
            // across evictions. (Hits scored afterwards by in-flight
            // sequences are the one loss.) Sequences still decoding
            // against the victim keep it alive through their own Rcs.
            let c = evicted.cache.borrow().counters();
            self.stats.grammar_mask_hits += c.hits;
            self.stats.grammar_mask_misses += c.misses;
            self.stats.grammar_mask_evictions += c.evictions;
        }
        entry
    }

    /// `runtime_stats_text` analog: a human-readable engine report. The
    /// scalar core (including the grammar compile/mask-cache counters)
    /// comes from [`EngineStats::stats_json`]; the live mask-cache
    /// hit/miss/eviction counters are folded into the snapshot here
    /// because the caches — not the stats struct — are their source of
    /// truth while the engine runs.
    pub fn stats_json(&self) -> Value {
        let mut stats = self.stats.clone();
        for entry in self.grammar_caches.values() {
            let c = entry.cache.borrow().counters();
            stats.grammar_mask_hits += c.hits;
            stats.grammar_mask_misses += c.misses;
            stats.grammar_mask_evictions += c.evictions;
        }
        // `shared_pages` is a high-water gauge: fold in the live pools so
        // a snapshot taken mid-family sees the current sharing too.
        for m in self.models.values() {
            stats.shared_pages = stats.shared_pages.max(m.kv.shared_pages() as u64);
        }
        let mut out = stats.stats_json();
        let mut models = Value::object();
        for (name, m) in &self.models {
            let (hits, misses) = m.kv.prefix_stats();
            // Queue depth per priority class: everything waiting for KV
            // (fresh admissions plus evicted residents awaiting resume).
            let mut by_prio = std::collections::BTreeMap::<i32, i64>::new();
            for p in &m.waiting {
                *by_prio.entry(p.req.priority).or_insert(0) += 1;
            }
            for p in &m.preempted {
                *by_prio.entry(p.seq.priority).or_insert(0) += 1;
            }
            let mut queued = Value::object();
            for (prio, n) in by_prio {
                queued.set(prio.to_string(), n);
            }
            models.set(
                name.clone(),
                crate::obj! {
                    "waiting" => m.waiting.len(),
                    "prefilling" => m.prefilling.len(),
                    "preempted" => m.preempted.len(),
                    "running" => m.running.len(),
                    "queued_by_priority" => queued,
                    "available_pages" => m.kv.available_pages(),
                    "prefix_cache_hits" => hits as i64,
                    "prefix_cache_misses" => misses as i64,
                    "load_seconds" => m.backend.load_seconds(),
                },
            );
        }
        out.set("models", models);
        out.set("draining", self.draining);
        out
    }
}

/// The draft model's own cheap sampler: greedy argmax at temperature
/// zero, plain softmax sampling otherwise, restricted to mask-allowed
/// tokens. Tokens in `banned` (the EOS set) are never proposed — ending
/// the stream is the target sampler's call, and keeping EOS out of the
/// proposal run keeps the rollback arithmetic one-directional. Returns
/// `None` when no token is proposable (then the round just ends early).
fn draft_pick(
    temperature: f32,
    rng: &mut Pcg32,
    logits: &[f32],
    mask: Option<&TokenBitmask>,
    banned: &[u32],
) -> Option<u32> {
    let allowed =
        |i: usize| mask.map_or(true, |m| m.is_allowed(i)) && !banned.contains(&(i as u32));
    if temperature <= 0.0 {
        let mut best: Option<(usize, f32)> = None;
        for (i, &l) in logits.iter().enumerate() {
            if !allowed(i) {
                continue;
            }
            // First-wins ties, matching the target's greedy argmax.
            if best.map_or(true, |(_, b)| l > b) {
                best = Some((i, l));
            }
        }
        return best.map(|(i, _)| i as u32);
    }
    let mut max = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        if allowed(i) && l > max {
            max = l;
        }
    }
    if max == f32::NEG_INFINITY {
        return None;
    }
    let mut total = 0f64;
    for (i, &l) in logits.iter().enumerate() {
        if allowed(i) {
            total += (((l - max) / temperature) as f64).exp();
        }
    }
    let target = rng.f32() as f64 * total;
    let mut acc = 0f64;
    let mut last = None;
    for (i, &l) in logits.iter().enumerate() {
        if !allowed(i) {
            continue;
        }
        acc += (((l - max) / temperature) as f64).exp();
        last = Some(i as u32);
        if acc >= target {
            return last;
        }
    }
    // Float underflow on the final slice: fall back to the last allowed.
    last
}

/// Absolute deadline for a request admitted at `t_admit` with an
/// effective `deadline_ms` (the request's own, or the engine default).
/// `None` in, or an overflowing add, means no deadline.
fn deadline_at(t_admit: Instant, deadline_ms: Option<u64>) -> Option<Instant> {
    t_admit.checked_add(Duration::from_millis(deadline_ms?))
}

/// Whether a logits row is usable: every entry finite. A single NaN/Inf
/// poisons softmax for the whole row, so the row's request must fail —
/// but only that request (per-request error isolation).
fn row_is_finite(row: &[f32]) -> bool {
    row.iter().all(|l| l.is_finite())
}

/// Run `op`, absorbing transient backend faults with bounded
/// exponential-backoff retries. Counts every observed fault in
/// `stats`. Exhausting the retry budget escalates to `DeviceLost` —
/// a fault that persists across retries is treated like a lost device
/// and triggers a full reset — and an injected `DeviceLost` passes
/// straight through (retrying a lost device is pointless). Internal
/// errors also pass through untouched.
fn with_retries<T>(
    stats: &mut EngineStats,
    mut op: impl FnMut() -> Result<T, RuntimeError>,
) -> Result<T, RuntimeError> {
    let mut attempt: u32 = 0;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(RuntimeError::Transient(m)) => {
                stats.faults_injected += 1;
                if attempt >= MAX_TRANSIENT_RETRIES {
                    return Err(RuntimeError::DeviceLost(format!(
                        "transient fault persisted through {MAX_TRANSIENT_RETRIES} retries: {m}"
                    )));
                }
                stats.transient_retries += 1;
                std::thread::sleep(Duration::from_micros(50 << attempt));
                attempt += 1;
            }
            Err(e @ RuntimeError::DeviceLost(_)) => {
                stats.faults_injected += 1;
                return Err(e);
            }
            Err(e) => return Err(e),
        }
    }
}
