//! The paper's system contribution (Figure 1): a two-engine architecture
//! with an OpenAI-style JSON message boundary between them.
//!
//! * [`engine::MLCEngine`] — the backend engine that "actually computes
//!   the LLM workload": continuous-batching scheduler, paged KV cache,
//!   sampling, grammar-constrained decoding, streaming detokenization,
//!   multi-model loading. Runs wherever it's constructed — in-process
//!   ("native mode", the MLC-LLM baseline) or inside a worker thread.
//! * [`worker::WorkerHandle`] — the web-worker analog: a dedicated thread
//!   owning an `MLCEngine`, driven by a `postMessage`-style JSON channel.
//! * [`frontend::ServiceWorkerMLCEngine`] — the lightweight frontend
//!   handle web apps would instantiate: endpoint-like, JSON-in-JSON-out,
//!   talks only through the worker channel.
//! * [`messages`] — the wire protocol (OpenAI requests/responses in JSON
//!   envelopes), exactly the messages of the paper's §2.2.

pub mod engine;
pub mod frontend;
pub mod messages;
pub mod worker;

pub use engine::{
    BackendKind, EngineConfig, EngineEvent, MLCEngine, RequestId, DEFAULT_MASK_CACHE_CAPACITY,
    DEFAULT_MAX_CONCURRENT_PREFILLS, DEFAULT_MAX_WAITING_REQUESTS, DEFAULT_PREFILL_TOKEN_BUDGET,
    DEFAULT_SPEC_TOKENS,
};
pub use frontend::ServiceWorkerMLCEngine;
pub use messages::{FromWorker, ToWorker};
pub use worker::WorkerHandle;
