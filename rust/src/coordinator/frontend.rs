//! `ServiceWorkerMLCEngine` — the lightweight frontend engine a web app
//! instantiates (paper §2.1): endpoint-like behavior, OpenAI-style
//! JSON-in-JSON-out, all computation delegated to the worker over the
//! message channel.

use super::messages::{FromWorker, ToWorker};
use super::worker::WorkerHandle;
use super::EngineConfig;
use crate::api::{ApiError, ChatChunk, ChatCompletionRequest, ChatCompletionResponse};
use crate::json::Value;
use std::collections::VecDeque;
use std::time::Duration;

pub struct ServiceWorkerMLCEngine {
    worker: WorkerHandle,
    models: Vec<String>,
    next_id: u64,
    /// Buffered out-of-order messages (e.g. chunks for another request).
    pending: VecDeque<FromWorker>,
    /// Bound on any single wait for the worker (`--engine-timeout`);
    /// generous by default because CPU-PJRT decode of the larger model is
    /// ~100ms+/token.
    timeout: Duration,
}

impl ServiceWorkerMLCEngine {
    /// Create the engine: spawns the worker, which loads the models.
    pub fn create(cfg: EngineConfig) -> Result<Self, ApiError> {
        let timeout = cfg.engine_timeout();
        let (worker, models) =
            WorkerHandle::spawn(cfg).map_err(ApiError::internal)?;
        Ok(Self { worker, models, next_id: 1, pending: VecDeque::new(), timeout })
    }

    pub fn models(&self) -> &[String] {
        &self.models
    }

    /// Non-streaming completion: returns the full response.
    pub fn chat_completion(
        &mut self,
        mut request: ChatCompletionRequest,
    ) -> Result<ChatCompletionResponse, ApiError> {
        request.stream = false;
        let id = self.post(request)?;
        loop {
            match self.next_message_for(id)? {
                FromWorker::Done { response, .. } => return Ok(response),
                FromWorker::Error { error, .. } => return Err(error),
                _ => {} // stray chunk (request was non-streaming) — ignore
            }
        }
    }

    /// Streaming completion: `on_chunk` sees every delta; returns the
    /// final response.
    pub fn chat_completion_stream(
        &mut self,
        mut request: ChatCompletionRequest,
        mut on_chunk: impl FnMut(&ChatChunk),
    ) -> Result<ChatCompletionResponse, ApiError> {
        request.stream = true;
        let id = self.post(request)?;
        loop {
            match self.next_message_for(id)? {
                FromWorker::Chunk { chunk, .. } => on_chunk(&chunk),
                FromWorker::Done { response, .. } => return Ok(response),
                FromWorker::Error { error, .. } => return Err(error),
                _ => {}
            }
        }
    }

    /// Fire-and-forget submission for concurrent workloads (the serve
    /// driver fans out many requests, then drains with `poll`).
    pub fn submit(&mut self, request: ChatCompletionRequest) -> Result<u64, ApiError> {
        self.post(request)
    }

    /// Next message for any request (concurrent mode).
    pub fn poll(&mut self, timeout: Duration) -> Result<FromWorker, ApiError> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(m);
        }
        self.worker.recv(timeout).map_err(ApiError::internal)
    }

    pub fn abort(&mut self, id: u64) -> Result<(), ApiError> {
        self.worker.post(&ToWorker::Abort { id }).map_err(ApiError::internal)
    }

    /// Begin a graceful drain: the worker stops admitting immediately;
    /// resident requests keep streaming (bounded by `timeout_ms` when
    /// given). Returns without waiting — pair with [`Self::wait_drained`].
    pub fn drain(&mut self, timeout_ms: Option<u64>) -> Result<(), ApiError> {
        self.worker.post(&ToWorker::Drain { timeout_ms }).map_err(ApiError::internal)
    }

    /// Block until the worker announces the drain is complete, buffering
    /// (not dropping) any in-flight completion traffic seen on the way.
    pub fn wait_drained(&mut self) -> Result<(), ApiError> {
        if let Some(i) = self.pending.iter().position(|m| matches!(m, FromWorker::Drained)) {
            self.pending.remove(i);
            return Ok(());
        }
        loop {
            match self.worker.recv(self.timeout).map_err(ApiError::internal)? {
                FromWorker::Drained => return Ok(()),
                other => self.pending.push_back(other),
            }
        }
    }

    /// Engine runtime stats (the `runtime_stats_text` analog).
    pub fn stats(&mut self) -> Result<Value, ApiError> {
        self.worker.post(&ToWorker::Stats).map_err(ApiError::internal)?;
        loop {
            match self.poll(self.timeout)? {
                FromWorker::Stats { payload } => return Ok(payload),
                other => self.pending.push_back(other),
            }
        }
    }

    fn post(&mut self, request: ChatCompletionRequest) -> Result<u64, ApiError> {
        let id = self.next_id;
        self.next_id += 1;
        self.worker
            .post(&ToWorker::ChatCompletion { id, request })
            .map_err(ApiError::internal)?;
        Ok(id)
    }

    fn next_message_for(&mut self, id: u64) -> Result<FromWorker, ApiError> {
        // Serve buffered messages for this id first.
        if let Some(idx) = self.pending.iter().position(|m| message_id(m) == Some(id)) {
            return Ok(self.pending.remove(idx).unwrap());
        }
        loop {
            let msg = self.worker.recv(self.timeout).map_err(ApiError::internal)?;
            if message_id(&msg) == Some(id) {
                return Ok(msg);
            }
            self.pending.push_back(msg);
        }
    }
}

fn message_id(m: &FromWorker) -> Option<u64> {
    match m {
        FromWorker::Chunk { id, .. }
        | FromWorker::Done { id, .. }
        | FromWorker::Error { id, .. } => Some(*id),
        _ => None,
    }
}
