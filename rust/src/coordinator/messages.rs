//! The `postMessage` wire protocol between the frontend engine and the
//! worker engine (paper §2.2: "the two engines communicate via message-
//! passing, and the messages are simply OpenAI-style requests and
//! responses").
//!
//! Every message is a JSON envelope `{"kind": ..., "id": ..., "payload":
//! ...}` carried as a **serialized string** over the channel — the
//! serialize/parse round-trip is intentional: it is the structured-clone
//! cost a real browser pays, and the worker-overhead bench measures it.

use crate::api::{ApiError, ChatChunk, ChatCompletionRequest, ChatCompletionResponse};
use crate::json::{parse, to_string, Value};

/// Frontend -> worker.
#[derive(Debug)]
pub enum ToWorker {
    ChatCompletion { id: u64, request: ChatCompletionRequest },
    Abort { id: u64 },
    Stats,
    /// Graceful drain: stop admitting, finish residents (within
    /// `timeout_ms` when given), then announce [`FromWorker::Drained`].
    Drain { timeout_ms: Option<u64> },
    Shutdown,
}

/// Worker -> frontend.
#[derive(Debug)]
pub enum FromWorker {
    Chunk { id: u64, chunk: ChatChunk },
    Done { id: u64, response: ChatCompletionResponse },
    Error { id: u64, error: ApiError },
    Stats { payload: Value },
    /// Worker finished loading models and is ready for requests.
    Ready { models: Vec<String> },
    /// Drain complete: every resident request resolved, none admitted.
    Drained,
}

impl ToWorker {
    pub fn to_wire(&self) -> String {
        let v = match self {
            ToWorker::ChatCompletion { id, request } => crate::obj! {
                "kind" => "chat_completion",
                "id" => *id as i64,
                "payload" => request.to_json(),
            },
            ToWorker::Abort { id } => crate::obj! {
                "kind" => "abort",
                "id" => *id as i64,
            },
            ToWorker::Stats => crate::obj! { "kind" => "stats" },
            ToWorker::Drain { timeout_ms } => {
                let mut v = crate::obj! { "kind" => "drain" };
                if let Some(ms) = timeout_ms {
                    v.set("timeout_ms", *ms as i64);
                }
                v
            }
            ToWorker::Shutdown => crate::obj! { "kind" => "shutdown" },
        };
        to_string(&v)
    }

    pub fn from_wire(wire: &str) -> Result<Self, String> {
        let v = parse(wire).map_err(|e| e.to_string())?;
        let kind = v.get("kind").and_then(Value::as_str).ok_or("missing kind")?;
        let id = || v.get("id").and_then(Value::as_u64).ok_or("missing id");
        match kind {
            "chat_completion" => Ok(ToWorker::ChatCompletion {
                id: id()?,
                request: ChatCompletionRequest::from_json(
                    v.get("payload").ok_or("missing payload")?,
                )
                .map_err(|e| e.to_string())?,
            }),
            "abort" => Ok(ToWorker::Abort { id: id()? }),
            "stats" => Ok(ToWorker::Stats),
            "drain" => Ok(ToWorker::Drain {
                timeout_ms: v.get("timeout_ms").and_then(Value::as_u64),
            }),
            "shutdown" => Ok(ToWorker::Shutdown),
            other => Err(format!("unknown message kind '{other}'")),
        }
    }
}

impl FromWorker {
    pub fn to_wire(&self) -> String {
        let v = match self {
            FromWorker::Chunk { id, chunk } => crate::obj! {
                "kind" => "chunk",
                "id" => *id as i64,
                "payload" => chunk.to_json(),
            },
            FromWorker::Done { id, response } => crate::obj! {
                "kind" => "done",
                "id" => *id as i64,
                "payload" => response.to_json(),
            },
            FromWorker::Error { id, error } => crate::obj! {
                "kind" => "error",
                "id" => *id as i64,
                "payload" => error.to_json(),
            },
            FromWorker::Stats { payload } => crate::obj! {
                "kind" => "stats",
                "payload" => payload.clone(),
            },
            FromWorker::Ready { models } => crate::obj! {
                "kind" => "ready",
                "payload" => models.clone(),
            },
            FromWorker::Drained => crate::obj! { "kind" => "drained" },
        };
        to_string(&v)
    }

    pub fn from_wire(wire: &str) -> Result<Self, String> {
        let v = parse(wire).map_err(|e| e.to_string())?;
        let kind = v.get("kind").and_then(Value::as_str).ok_or("missing kind")?;
        let id = || v.get("id").and_then(Value::as_u64).ok_or("missing id");
        let payload = || v.get("payload").ok_or("missing payload");
        match kind {
            "chunk" => Ok(FromWorker::Chunk {
                id: id()?,
                chunk: ChatChunk::from_json(payload()?).ok_or("bad chunk")?,
            }),
            "done" => Ok(FromWorker::Done {
                id: id()?,
                response: ChatCompletionResponse::from_json(payload()?).ok_or("bad response")?,
            }),
            "error" => Ok(FromWorker::Error {
                id: id()?,
                error: ApiError::from_json(payload()?).ok_or("bad error")?,
            }),
            "stats" => Ok(FromWorker::Stats { payload: payload()?.clone() }),
            "ready" => Ok(FromWorker::Ready {
                models: payload()?
                    .as_array()
                    .ok_or("bad ready payload")?
                    .iter()
                    .filter_map(|m| m.as_str().map(String::from))
                    .collect(),
            }),
            "drained" => Ok(FromWorker::Drained),
            other => Err(format!("unknown message kind '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::FinishReason;

    #[test]
    fn to_worker_roundtrip() {
        let req = ChatCompletionRequest::new("tiny-2m").user("hello");
        let msg = ToWorker::ChatCompletion { id: 42, request: req };
        let wire = msg.to_wire();
        match ToWorker::from_wire(&wire).unwrap() {
            ToWorker::ChatCompletion { id, request } => {
                assert_eq!(id, 42);
                assert_eq!(request.model, "tiny-2m");
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(ToWorker::from_wire(r#"{"kind":"stats"}"#).unwrap(), ToWorker::Stats));
        assert!(ToWorker::from_wire(r#"{"kind":"nope"}"#).is_err());
        assert!(ToWorker::from_wire("not json").is_err());
    }

    #[test]
    fn drain_roundtrip() {
        let wire = ToWorker::Drain { timeout_ms: Some(250) }.to_wire();
        match ToWorker::from_wire(&wire).unwrap() {
            ToWorker::Drain { timeout_ms } => assert_eq!(timeout_ms, Some(250)),
            other => panic!("{other:?}"),
        }
        // No bound => drain waits for residents indefinitely.
        match ToWorker::from_wire(r#"{"kind":"drain"}"#).unwrap() {
            ToWorker::Drain { timeout_ms } => assert_eq!(timeout_ms, None),
            other => panic!("{other:?}"),
        }
        let wire = FromWorker::Drained.to_wire();
        assert!(matches!(FromWorker::from_wire(&wire).unwrap(), FromWorker::Drained));
    }

    #[test]
    fn from_worker_roundtrip() {
        let chunk = ChatChunk {
            id: "c".into(),
            model: "m".into(),
            index: 1,
            delta: "hi".into(),
            finish_reason: Some(FinishReason::Stop),
            usage: None,
        };
        let wire = FromWorker::Chunk { id: 7, chunk: chunk.clone() }.to_wire();
        match FromWorker::from_wire(&wire).unwrap() {
            FromWorker::Chunk { id, chunk: c } => {
                assert_eq!(id, 7);
                assert_eq!(c, chunk);
            }
            other => panic!("{other:?}"),
        }
        let wire = FromWorker::Ready { models: vec!["a".into(), "b".into()] }.to_wire();
        match FromWorker::from_wire(&wire).unwrap() {
            FromWorker::Ready { models } => assert_eq!(models, vec!["a", "b"]),
            other => panic!("{other:?}"),
        }
    }
}
