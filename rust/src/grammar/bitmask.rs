//! Packed vocabulary bitmask: one `u64` word per 64 token ids.
//!
//! The decode hot path hands a mask from the grammar engine to the sampler
//! every token. With a `Vec<bool>` that is a vocab-sized buffer (128 KiB at
//! a 128k vocab) that gets allocated, filled, cloned on cache hits, and
//! scanned bit-by-bit. Packing it XGrammar-style makes the mask 64× smaller,
//! makes cache hits an `Rc` pointer clone, and — the part that matters for
//! sampling — lets the sampler *skip 64 banned tokens per word test*
//! (`word == 0`) instead of branching per token.
//!
//! Invariant: bits at positions `>= len` (the tail of the last word) are
//! always zero. Every constructor and mutator maintains this, so word-level
//! consumers (popcount, `words()`, iteration) never see phantom tokens.

/// A packed allow/ban mask over token ids `0..len`. Bit set = allowed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenBitmask {
    words: Vec<u64>,
    len: usize,
}

impl TokenBitmask {
    /// All tokens banned (the matcher starts from nothing-allowed and
    /// grants bits).
    pub fn new(len: usize) -> Self {
        Self {
            words: vec![0u64; len.div_ceil(64)],
            len,
        }
    }

    /// All tokens allowed.
    pub fn all_allowed(len: usize) -> Self {
        let mut m = Self {
            words: vec![!0u64; len.div_ceil(64)],
            len,
        };
        m.clear_tail();
        m
    }

    pub fn from_bools(bools: &[bool]) -> Self {
        let mut m = Self::new(bools.len());
        for (i, &ok) in bools.iter().enumerate() {
            if ok {
                m.allow(i);
            }
        }
        m
    }

    /// Expand to the unpacked representation (tests, compatibility shims).
    pub fn to_bools(&self) -> Vec<bool> {
        (0..self.len).map(|i| self.is_allowed(i)).collect()
    }

    /// Number of token ids covered (the vocab size, not the allowed count).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the mask covers zero token ids (`len == 0`), *not* when
    /// all tokens are banned — see [`TokenBitmask::any_allowed`] for that.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed words; bits past `len` are guaranteed zero.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Whether token `i` is allowed.
    #[inline]
    pub fn is_allowed(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Allow token `i` (set its bit).
    #[inline]
    pub fn allow(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Ban token `i` (clear its bit).
    #[inline]
    pub fn ban(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    /// Intersect with another mask of the same length (e.g. stacking a
    /// stop-token ban on top of a grammar mask).
    pub fn and_with(&mut self, other: &TokenBitmask) {
        debug_assert_eq!(self.len, other.len);
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w &= o;
        }
    }

    /// Union with another mask of the same length.
    pub fn or_with(&mut self, other: &TokenBitmask) {
        debug_assert_eq!(self.len, other.len);
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w |= o;
        }
    }

    /// Remove every token allowed by `other` (set difference, in place).
    pub fn and_not_with(&mut self, other: &TokenBitmask) {
        debug_assert_eq!(self.len, other.len);
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w &= !o;
        }
    }

    /// The complement mask: every banned token becomes allowed and vice
    /// versa. The tail invariant is preserved (bits past `len` stay zero).
    pub fn complement(&self) -> Self {
        let mut m = Self {
            words: self.words.iter().map(|w| !w).collect(),
            len: self.len,
        };
        m.clear_tail();
        m
    }

    /// True when no token is allowed by both masks.
    pub fn is_disjoint(&self, other: &TokenBitmask) -> bool {
        debug_assert_eq!(self.len, other.len);
        self.words.iter().zip(&other.words).all(|(w, o)| w & o == 0)
    }

    /// True when every token allowed here is also allowed by `other`.
    pub fn is_subset_of(&self, other: &TokenBitmask) -> bool {
        debug_assert_eq!(self.len, other.len);
        self.words.iter().zip(&other.words).all(|(w, o)| w & !o == 0)
    }

    /// Popcount of the intersection, without materializing it.
    pub fn count_and(&self, other: &TokenBitmask) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(w, o)| (w & o).count_ones() as usize)
            .sum()
    }

    /// Popcount over the whole mask.
    pub fn count_allowed(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True when at least one token is allowed.
    pub fn any_allowed(&self) -> bool {
        self.words.iter().any(|&w| w != 0)
    }

    /// Iterate allowed token ids in ascending order, skipping 64 ids per
    /// zero word.
    pub fn iter_allowed(&self) -> AllowedIter<'_> {
        AllowedIter {
            words: &self.words,
            next_word: 0,
            current: 0,
            base: 0,
        }
    }

    fn clear_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }
}

/// `mask[i]` compatibility with the old `Vec<bool>` masks.
impl std::ops::Index<usize> for TokenBitmask {
    type Output = bool;

    fn index(&self, i: usize) -> &bool {
        if self.is_allowed(i) {
            &true
        } else {
            &false
        }
    }
}

pub struct AllowedIter<'a> {
    words: &'a [u64],
    next_word: usize,
    /// Remaining bits of the word currently being drained.
    current: u64,
    /// Token id of bit 0 of `current`.
    base: usize,
}

impl<'a> Iterator for AllowedIter<'a> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.current == 0 {
            if self.next_word >= self.words.len() {
                return None;
            }
            self.current = self.words[self.next_word];
            self.base = self.next_word * 64;
            self.next_word += 1;
        }
        let bit = self.current.trailing_zeros() as usize;
        self.current &= self.current - 1;
        Some(self.base + bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_test_roundtrip_across_word_boundaries() {
        for len in [1usize, 63, 64, 65, 130, 1000] {
            let mut m = TokenBitmask::new(len);
            assert_eq!(m.len(), len);
            assert_eq!(m.count_allowed(), 0);
            let picks: Vec<usize> =
                [0, len / 3, len / 2, len - 1].into_iter().filter(|&i| i < len).collect();
            for &i in &picks {
                m.allow(i);
            }
            for i in 0..len {
                assert_eq!(m.is_allowed(i), picks.contains(&i), "len {len} bit {i}");
                assert_eq!(m[i], picks.contains(&i));
            }
            let mut uniq = picks.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(m.count_allowed(), uniq.len());
            assert_eq!(m.iter_allowed().collect::<Vec<_>>(), uniq);
            for &i in &picks {
                m.ban(i);
            }
            assert!(!m.any_allowed());
        }
    }

    #[test]
    fn all_allowed_clears_tail_bits() {
        for len in [1usize, 63, 64, 65, 127, 129] {
            let m = TokenBitmask::all_allowed(len);
            assert_eq!(m.count_allowed(), len);
            let total_bits: usize = m.words().len() * 64;
            assert!(total_bits >= len);
            // tail invariant: popcount over words == len
            assert_eq!(
                m.words().iter().map(|w| w.count_ones() as usize).sum::<usize>(),
                len
            );
        }
    }

    #[test]
    fn bools_roundtrip() {
        let bools: Vec<bool> = (0..150).map(|i| i % 3 == 0 || i % 7 == 0).collect();
        let m = TokenBitmask::from_bools(&bools);
        assert_eq!(m.to_bools(), bools);
        assert_eq!(m.count_allowed(), bools.iter().filter(|&&b| b).count());
    }

    #[test]
    fn and_or_combine() {
        let a = TokenBitmask::from_bools(&[true, true, false, false, true]);
        let b = TokenBitmask::from_bools(&[true, false, true, false, true]);
        let mut and = a.clone();
        and.and_with(&b);
        assert_eq!(and.to_bools(), vec![true, false, false, false, true]);
        let mut or = a.clone();
        or.or_with(&b);
        assert_eq!(or.to_bools(), vec![true, true, true, false, true]);
    }

    #[test]
    fn set_ops_respect_len_and_tail() {
        for len in [5usize, 64, 70, 130] {
            let mut a = TokenBitmask::new(len);
            let mut b = TokenBitmask::new(len);
            for i in 0..len {
                if i % 2 == 0 {
                    a.allow(i);
                }
                if i % 3 == 0 {
                    b.allow(i);
                }
            }
            let c = a.complement();
            assert_eq!(c.count_allowed(), len - a.count_allowed(), "len {len}");
            assert!(a.is_disjoint(&c));
            let mut union = a.clone();
            union.or_with(&c);
            assert_eq!(union.count_allowed(), len, "complement partitions 0..len");
            assert_eq!(a.count_and(&b), (0..len).filter(|i| i % 6 == 0).count());
            let mut diff = a.clone();
            diff.and_not_with(&b);
            assert_eq!(diff.count_allowed(), a.count_allowed() - a.count_and(&b));
            assert!(diff.is_subset_of(&a));
            assert!(diff.is_disjoint(&b));
            assert!(!a.is_subset_of(&b), "evens are not a subset of multiples of 3");
        }
    }

    #[test]
    fn iter_skips_zero_words() {
        let mut m = TokenBitmask::new(64 * 40);
        m.allow(5);
        m.allow(64 * 20 + 1);
        m.allow(64 * 39 + 63);
        assert_eq!(
            m.iter_allowed().collect::<Vec<_>>(),
            vec![5, 64 * 20 + 1, 64 * 39 + 63]
        );
    }

    #[test]
    fn empty_mask() {
        let m = TokenBitmask::new(0);
        assert!(m.is_empty());
        assert!(!m.any_allowed());
        assert_eq!(m.iter_allowed().count(), 0);
    }
}
