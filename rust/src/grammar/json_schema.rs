//! JSON Schema -> grammar compiler (the `response_format: json_schema`
//! path of the OpenAI-style API, WebLLM §2.1).
//!
//! Supported keywords (full matrix in DESIGN.md §2): `type` (strings and
//! arrays), `enum`/`const` (any values), `anyOf`, `oneOf` (branches must
//! be provably disjoint by type/literal discriminators), `allOf` (merged
//! by keyword normalization), `$ref` into `#/$defs` or `#/definitions`
//! (recursion allowed), `properties`/`required`, `additionalProperties`
//! (`false`, `true`, or a value schema — typed maps when no properties
//! are declared), `items`/`prefixItems`/`minItems`/`maxItems`, string
//! `minLength`/`maxLength`/`pattern`/`format` (`date`, `date-time`,
//! `uuid`, `email`), and integer/number `minimum`/`maximum`/
//! `exclusiveMinimum`/`exclusiveMaximum` compiled to digit-DFA prefixes.
//! Unsupported or contradictory combinations are rejected with a
//! structured [`GrammarError::Schema`](super::GrammarError::Schema) —
//! never silently relaxed.
//!
//! Emitted JSON is **compact** (no inter-token whitespace) — the same
//! canonicalization XGrammar defaults to; it keeps token masks tight.
//! The grammar therefore describes a *canonical subset* of each schema's
//! instances: properties appear in schema order, numbers carry no
//! exponent or leading zeros, and pattern-constrained strings avoid
//! escapes. Every instance the grammar derives validates against the
//! schema; the conformance suite (`tests/test_schema_conformance.rs`)
//! cross-checks that against an independent oracle validator.

use super::grammar::{ByteClass, Grammar, GrammarError, Sym};
use super::regex;
use crate::json::Value;
use std::collections::HashMap;

/// Largest `maxItems`/`minItems`/`prefixItems` the compiler will expand.
const MAX_ARRAY_ITEMS: usize = 4096;
/// Largest `minLength`/`maxLength` the compiler will expand.
const MAX_STRING_LEN: usize = 1024;
/// Rule budget: a schema whose expansion exceeds this fails structurally
/// instead of exhausting memory (fuzz harness relies on it).
const MAX_SCHEMA_RULES: usize = 20_000;
/// Numeric bounds beyond this magnitude are rejected (exact in f64/i64).
const MAX_ABS_BOUND: f64 = 1e15;
/// allOf normalization depth cap (cyclic $ref chains through allOf).
const MAX_ALLOF_DEPTH: usize = 32;

/// Compile a JSON Schema (as a parsed [`Value`]) into a byte-level
/// [`Grammar`] matching its *compact* JSON serialization.
///
/// # Examples
///
/// ```
/// use std::rc::Rc;
/// use webllm::grammar::{schema_to_grammar, GrammarMatcher};
/// use webllm::json::parse;
///
/// let schema = parse(r#"{
///     "type": "object",
///     "properties": {"ok": {"type": "boolean"}},
///     "required": ["ok"]
/// }"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&schema).unwrap());
///
/// let mut m = GrammarMatcher::new(g.clone());
/// assert!(m.advance_bytes(br#"{"ok":true}"#) && m.is_accepting());
///
/// // The canon is compact: whitespace is not part of the language.
/// let mut m = GrammarMatcher::new(g);
/// assert!(!m.advance_bytes(br#"{ "ok": true }"#));
/// ```
///
/// Numeric bounds compile to digit-DFA prefixes and `type` accepts
/// arrays (nullable fields):
///
/// ```
/// use std::rc::Rc;
/// use webllm::grammar::{schema_to_grammar, GrammarMatcher};
/// use webllm::json::parse;
///
/// let schema = parse(r#"{"type": "integer", "minimum": 1, "maximum": 40}"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&schema).unwrap());
/// let ok = |s: &[u8]| { let mut m = GrammarMatcher::new(g.clone()); m.advance_bytes(s) && m.is_accepting() };
/// assert!(ok(b"7") && ok(b"40"));
/// assert!(!ok(b"0") && !ok(b"41"));
///
/// let nullable = parse(r#"{"type": ["string", "null"]}"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&nullable).unwrap());
/// let ok = |s: &[u8]| { let mut m = GrammarMatcher::new(g.clone()); m.advance_bytes(s) && m.is_accepting() };
/// assert!(ok(b"null") && ok(br#""x""#));
/// ```
///
/// String `pattern` (a bounded regex subset, see
/// [`regex_to_grammar`](super::regex_to_grammar)) and `format` compile to
/// concrete byte grammars; `additionalProperties` with a value schema
/// yields a typed map:
///
/// ```
/// use std::rc::Rc;
/// use webllm::grammar::{schema_to_grammar, GrammarMatcher};
/// use webllm::json::parse;
///
/// let schema = parse(r#"{"type": "string", "pattern": "[A-Z]{2}-[0-9]{3}"}"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&schema).unwrap());
/// let ok = |s: &[u8]| { let mut m = GrammarMatcher::new(g.clone()); m.advance_bytes(s) && m.is_accepting() };
/// assert!(ok(br#""AB-123""#));
/// assert!(!ok(br#""ab-123""#));
///
/// let map = parse(r#"{"type": "object", "additionalProperties": {"type": "integer"}}"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&map).unwrap());
/// let ok = |s: &[u8]| { let mut m = GrammarMatcher::new(g.clone()); m.advance_bytes(s) && m.is_accepting() };
/// assert!(ok(br#"{"a":1,"b":2}"#) && ok(b"{}"));
/// assert!(!ok(br#"{"a":true}"#));
/// ```
///
/// `allOf` branches are merged keyword-by-keyword; `prefixItems` gives
/// positional element types:
///
/// ```
/// use std::rc::Rc;
/// use webllm::grammar::{schema_to_grammar, GrammarMatcher};
/// use webllm::json::parse;
///
/// let schema = parse(r#"{"allOf": [
///     {"type": "object", "properties": {"a": {"type": "integer"}}, "required": ["a"]},
///     {"type": "object", "properties": {"b": {"type": "boolean"}}, "required": ["b"]}
/// ]}"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&schema).unwrap());
/// let ok = |s: &[u8]| { let mut m = GrammarMatcher::new(g.clone()); m.advance_bytes(s) && m.is_accepting() };
/// assert!(ok(br#"{"a":1,"b":true}"#));
///
/// let tuple = parse(r#"{"type": "array",
///     "prefixItems": [{"type": "integer"}, {"type": "string"}],
///     "items": false}"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&tuple).unwrap());
/// let ok = |s: &[u8]| { let mut m = GrammarMatcher::new(g.clone()); m.advance_bytes(s) && m.is_accepting() };
/// assert!(ok(br#"[1,"x"]"#));
/// assert!(!ok(br#"["x",1]"#));
/// ```
///
/// The empty schema (`{}`) matches any JSON value; unsupported keywords
/// produce [`GrammarError::Schema`](super::GrammarError::Schema).
pub fn schema_to_grammar(schema: &Value) -> Result<Grammar, GrammarError> {
    let mut c = Compiler {
        g: Grammar::new(),
        root_schema: schema,
        refs: HashMap::new(),
        shared: HashMap::new(),
        allof_depth: 0,
    };
    let root = c.g.add_rule("root");
    debug_assert_eq!(root, 0);
    let seq = c.compile(schema, "root")?;
    c.g.add_alt(0, seq);
    c.g.validate()?;
    Ok(c.g)
}

/// The anchored pattern implementing a supported `format`, shared between
/// the grammar compiler and the conformance-test oracle so both sides
/// agree on the (syntactic) language. Unknown formats return `None` and
/// are treated as annotations, per the spec's default vocabulary.
pub fn format_pattern(name: &str) -> Option<&'static str> {
    match name {
        "date" => Some("[0-9]{4}-(0[1-9]|1[0-2])-(0[1-9]|[12][0-9]|3[01])"),
        "date-time" => Some(
            "[0-9]{4}-(0[1-9]|1[0-2])-(0[1-9]|[12][0-9]|3[01])\
             T([01][0-9]|2[0-3]):[0-5][0-9]:[0-5][0-9](\\.[0-9]{1,9})?\
             (Z|[+-]([01][0-9]|2[0-3]):[0-5][0-9])",
        ),
        "uuid" => Some(
            "[0-9a-f]{8}-[0-9a-f]{4}-[0-9a-f]{4}-[0-9a-f]{4}-[0-9a-f]{12}",
        ),
        "email" => Some("[A-Za-z0-9._%+-]+@[A-Za-z0-9.-]+\\.[A-Za-z]{2,8}"),
        _ => None,
    }
}

/// Type-kind bits for oneOf disjointness discrimination.
const K_NULL: u8 = 1;
const K_BOOL: u8 = 2;
const K_NUM: u8 = 4;
const K_STR: u8 = 8;
const K_OBJ: u8 = 16;
const K_ARR: u8 = 32;

/// What provably distinguishes a oneOf branch: a set of JSON type kinds,
/// or a finite set of literal serializations (const/enum).
enum Disc {
    Kinds(u8),
    Lits(Vec<String>),
}

impl Disc {
    fn kinds(&self) -> u8 {
        match self {
            Disc::Kinds(k) => *k,
            Disc::Lits(ls) => ls.iter().fold(0, |acc, l| acc | lit_kind(l)),
        }
    }
}

fn kind_bit(t: &str) -> Option<u8> {
    Some(match t {
        "null" => K_NULL,
        "boolean" => K_BOOL,
        "number" | "integer" => K_NUM,
        "string" => K_STR,
        "object" => K_OBJ,
        "array" => K_ARR,
        _ => return None,
    })
}

/// The kind of a serialized literal, by its first byte.
fn lit_kind(s: &str) -> u8 {
    match s.as_bytes().first() {
        Some(b'"') => K_STR,
        Some(b't') | Some(b'f') => K_BOOL,
        Some(b'n') => K_NULL,
        Some(b'{') => K_OBJ,
        Some(b'[') => K_ARR,
        _ => K_NUM,
    }
}

fn disjoint(a: &Disc, b: &Disc) -> bool {
    match (a, b) {
        (Disc::Lits(x), Disc::Lits(y)) => !x.iter().any(|l| y.contains(l)),
        _ => a.kinds() & b.kinds() == 0,
    }
}

fn wrap_alts(g: &mut Grammar, mut alts: Vec<Vec<Sym>>, hint: &str) -> Vec<Sym> {
    if alts.len() == 1 {
        alts.pop().unwrap()
    } else {
        vec![g.choice(alts, hint)]
    }
}

fn digit(lo: u8, hi: u8) -> Sym {
    Sym::Class(ByteClass { ranges: vec![(lo, hi)], negated: false })
}

/// Alternatives matching the decimal digit strings in `[lo, hi]`
/// position-by-position (equal lengths; leading zeros allowed — the
/// caller constrains the first digit).
fn digits_range(g: &mut Grammar, lo: &[u8], hi: &[u8], hint: &str) -> Vec<Vec<Sym>> {
    debug_assert_eq!(lo.len(), hi.len());
    if lo.is_empty() {
        return vec![Vec::new()];
    }
    if lo.iter().all(|&b| b == b'0') && hi.iter().all(|&b| b == b'9') {
        return vec![(0..lo.len()).map(|_| digit(b'0', b'9')).collect()];
    }
    let rest = lo.len() - 1;
    if lo[0] == hi[0] {
        let sub = digits_range(g, &lo[1..], &hi[1..], hint);
        let mut seq = vec![digit(lo[0], lo[0])];
        seq.extend(wrap_alts(g, sub, hint));
        return vec![seq];
    }
    let mut alts = Vec::new();
    {
        let nines = vec![b'9'; rest];
        let sub = digits_range(g, &lo[1..], &nines, hint);
        let mut seq = vec![digit(lo[0], lo[0])];
        seq.extend(wrap_alts(g, sub, hint));
        alts.push(seq);
    }
    if hi[0] - lo[0] >= 2 {
        let mut seq = vec![digit(lo[0] + 1, hi[0] - 1)];
        for _ in 0..rest {
            seq.push(digit(b'0', b'9'));
        }
        alts.push(seq);
    }
    {
        let zeros = vec![b'0'; rest];
        let sub = digits_range(g, &zeros, &hi[1..], hint);
        let mut seq = vec![digit(hi[0], hi[0])];
        seq.extend(wrap_alts(g, sub, hint));
        alts.push(seq);
    }
    alts
}

/// Alternatives matching the canonical decimal form (no leading zeros) of
/// every integer in `[a, b]` (or `[a, ∞)` when `b` is `None`).
fn pos_range_alts(g: &mut Grammar, a: u64, b: Option<u64>, hint: &str) -> Vec<Vec<Sym>> {
    let a_s = a.to_string().into_bytes();
    let mut alts = Vec::new();
    match b {
        Some(bv) => {
            debug_assert!(a <= bv);
            let b_s = bv.to_string().into_bytes();
            if a_s.len() == b_s.len() {
                alts.extend(digits_range(g, &a_s, &b_s, hint));
            } else {
                let nines = vec![b'9'; a_s.len()];
                alts.extend(digits_range(g, &a_s, &nines, hint));
                for d in a_s.len() + 1..b_s.len() {
                    let mut seq = vec![digit(b'1', b'9')];
                    for _ in 1..d {
                        seq.push(digit(b'0', b'9'));
                    }
                    alts.push(seq);
                }
                let mut low = vec![b'0'; b_s.len()];
                low[0] = b'1';
                alts.extend(digits_range(g, &low, &b_s, hint));
            }
        }
        None => {
            let nines = vec![b'9'; a_s.len()];
            alts.extend(digits_range(g, &a_s, &nines, hint));
            // Any canonical integer with strictly more digits.
            let mut seq = vec![digit(b'1', b'9')];
            for _ in 0..a_s.len() {
                seq.push(digit(b'0', b'9'));
            }
            seq.push(g.star(vec![digit(b'0', b'9')], hint));
            alts.push(seq);
        }
    }
    alts
}

/// Raw numeric bounds as read from the schema (value, before exclusivity
/// adjustment).
#[derive(Default)]
struct RawBounds {
    min: Option<f64>,
    emin: Option<f64>,
    max: Option<f64>,
    emax: Option<f64>,
}

impl RawBounds {
    fn any(&self) -> bool {
        self.min.is_some() || self.emin.is_some() || self.max.is_some() || self.emax.is_some()
    }
}

struct Compiler<'a> {
    g: Grammar,
    root_schema: &'a Value,
    /// $ref path -> rule index (memoized; enables recursive schemas).
    refs: HashMap<String, usize>,
    /// Shared primitive rules ("string", "number", ...) by name.
    shared: HashMap<&'static str, usize>,
    /// allOf normalization recursion depth (cycle guard).
    allof_depth: usize,
}

impl<'a> Compiler<'a> {
    fn err(m: impl Into<String>) -> GrammarError {
        GrammarError::Schema(m.into())
    }

    fn compile(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        if self.g.rules.len() > MAX_SCHEMA_RULES {
            return Err(Self::err("schema grammar exceeds rule budget"));
        }
        match schema {
            // `true` / `{}` -> any JSON value.
            Value::Bool(true) => Ok(vec![Sym::Ref(self.any_value())]),
            Value::Bool(false) => Err(Self::err("schema 'false' matches nothing")),
            Value::Object(o) if o.is_empty() => Ok(vec![Sym::Ref(self.any_value())]),
            Value::Object(_) => self.compile_object_schema(schema, hint),
            _ => Err(Self::err("schema must be an object or boolean")),
        }
    }

    fn compile_object_schema(
        &mut self,
        schema: &Value,
        hint: &str,
    ) -> Result<Vec<Sym>, GrammarError> {
        if let Some(r) = schema.get("$ref").and_then(Value::as_str) {
            return Ok(vec![Sym::Ref(self.resolve_ref(r)?)]);
        }
        if schema.get("allOf").is_some() {
            if self.allof_depth >= MAX_ALLOF_DEPTH {
                return Err(Self::err("allOf nesting too deep (cyclic $ref?)"));
            }
            let merged = self.merge_all_of(schema)?;
            self.allof_depth += 1;
            let r = self.compile(&merged, hint);
            self.allof_depth -= 1;
            return r;
        }
        if let Some(c) = schema.get("const") {
            return Ok(Grammar::lit(crate::json::to_string(c).as_bytes()));
        }
        if let Some(e) = schema.get("enum").and_then(Value::as_array) {
            let alts: Vec<Vec<Sym>> = e
                .iter()
                .map(|v| Grammar::lit(crate::json::to_string(v).as_bytes()))
                .collect();
            if alts.is_empty() {
                return Err(Self::err("empty enum"));
            }
            return Ok(vec![self.g.choice(alts, hint)]);
        }
        if let Some(list) = schema.get("anyOf").and_then(Value::as_array) {
            return self.alternation(list, hint, "anyOf");
        }
        if let Some(list) = schema.get("oneOf").and_then(Value::as_array) {
            // oneOf means *exactly one* branch validates. A CFG union can
            // only express that when the branches are pairwise disjoint —
            // check it via type/literal discriminators, otherwise reject
            // (see DESIGN.md §2; pinned by a corpus fixture).
            let discs: Vec<Option<Disc>> = list.iter().map(|s| self.discriminator(s, 0)).collect();
            for i in 0..discs.len() {
                for j in i + 1..discs.len() {
                    let ok = match (&discs[i], &discs[j]) {
                        (Some(a), Some(b)) => disjoint(a, b),
                        _ => false,
                    };
                    if !ok {
                        return Err(Self::err(format!(
                            "oneOf branches {i} and {j} are not provably disjoint \
                             (need distinct types or distinct const/enum literals); \
                             use anyOf for overlapping unions"
                        )));
                    }
                }
            }
            return self.alternation(list, hint, "oneOf");
        }

        match schema.get("type") {
            Some(Value::String(t)) => self.compile_typed(t, schema, hint),
            Some(Value::Array(ts)) => {
                if ts.is_empty() {
                    return Err(Self::err("empty 'type' array"));
                }
                let mut alts = Vec::new();
                for t in ts {
                    let t = t
                        .as_str()
                        .ok_or_else(|| Self::err("'type' array entries must be strings"))?;
                    alts.push(self.compile_typed(t, schema, &format!("{hint}.{t}"))?);
                }
                Ok(wrap_alts(&mut self.g, alts, hint))
            }
            Some(_) => Err(Self::err("'type' must be a string or array of strings")),
            None => Ok(vec![Sym::Ref(self.any_value())]),
        }
    }

    fn alternation(
        &mut self,
        list: &[Value],
        hint: &str,
        key: &str,
    ) -> Result<Vec<Sym>, GrammarError> {
        let mut alts = Vec::new();
        for (i, s) in list.iter().enumerate() {
            alts.push(self.compile(s, &format!("{hint}.{key}{i}"))?);
        }
        if alts.is_empty() {
            return Err(Self::err(format!("empty {key}")));
        }
        Ok(vec![self.g.choice(alts, hint)])
    }

    /// One `type` keyword applied with its sibling constraints.
    fn compile_typed(
        &mut self,
        t: &str,
        schema: &Value,
        hint: &str,
    ) -> Result<Vec<Sym>, GrammarError> {
        match t {
            "string" => self.string_schema(schema, hint),
            "number" => self.number_schema(schema, hint),
            "integer" => self.integer_schema(schema, hint),
            "boolean" => Ok(vec![self.g.choice(
                vec![Grammar::lit(b"true"), Grammar::lit(b"false")],
                hint,
            )]),
            "null" => Ok(Grammar::lit(b"null")),
            "object" => self.object_rule(schema, hint),
            "array" => self.array_rule(schema, hint),
            other => Err(Self::err(format!("unsupported type '{other}'"))),
        }
    }

    // -- oneOf discrimination -----------------------------------------------

    /// Read-only $defs lookup (no rule registration) for discrimination.
    fn ref_target(&self, path: &str) -> Option<&'a Value> {
        let target = path
            .strip_prefix("#/$defs/")
            .or_else(|| path.strip_prefix("#/definitions/"))?;
        self.root_schema
            .get("$defs")
            .or_else(|| self.root_schema.get("definitions"))?
            .get(target)
    }

    fn discriminator(&self, schema: &Value, depth: usize) -> Option<Disc> {
        if depth > 16 {
            return None;
        }
        let o = schema.as_object()?;
        if let Some(r) = o.get("$ref").and_then(Value::as_str) {
            return self.discriminator(self.ref_target(r)?, depth + 1);
        }
        if let Some(c) = o.get("const") {
            return Some(Disc::Lits(vec![crate::json::to_string(c)]));
        }
        if let Some(e) = o.get("enum").and_then(Value::as_array) {
            return Some(Disc::Lits(e.iter().map(crate::json::to_string).collect()));
        }
        if o.get("allOf").is_some() {
            return None;
        }
        for key in ["anyOf", "oneOf"] {
            if let Some(list) = o.get(key).and_then(Value::as_array) {
                let branches: Option<Vec<Disc>> =
                    list.iter().map(|s| self.discriminator(s, depth + 1)).collect();
                let branches = branches?;
                if branches.iter().all(|d| matches!(d, Disc::Lits(_))) {
                    let mut lits = Vec::new();
                    for d in branches {
                        if let Disc::Lits(ls) = d {
                            lits.extend(ls);
                        }
                    }
                    return Some(Disc::Lits(lits));
                }
                return Some(Disc::Kinds(branches.iter().fold(0, |acc, d| acc | d.kinds())));
            }
        }
        match o.get("type") {
            Some(Value::String(t)) => kind_bit(t).map(Disc::Kinds),
            Some(Value::Array(ts)) => {
                let mut bits = 0u8;
                for t in ts {
                    bits |= kind_bit(t.as_str()?)?;
                }
                Some(Disc::Kinds(bits))
            }
            _ => None,
        }
    }

    // -- allOf normalization ------------------------------------------------

    /// Resolve a pure `{"$ref": ...}` branch to its target (chains
    /// depth-limited); anything else passes through.
    fn deref_schema<'b>(
        &'b self,
        schema: &'b Value,
        depth: usize,
    ) -> Result<&'b Value, GrammarError> {
        if depth > MAX_ALLOF_DEPTH {
            return Err(Self::err("$ref chain too deep (cyclic?)"));
        }
        if let Some(o) = schema.as_object() {
            if o.len() == 1 {
                if let Some(r) = o.get("$ref").and_then(Value::as_str) {
                    let target = self
                        .ref_target(r)
                        .ok_or_else(|| Self::err(format!("unresolved $ref '{r}'")))?;
                    return self.deref_schema(target, depth + 1);
                }
            }
        }
        Ok(schema)
    }

    /// Merge `allOf` branches plus sibling keywords into one schema value.
    /// Keywords we can intersect are intersected (`type`, bounds, `enum`,
    /// `const`); `required` unions; same-name `properties` nest as
    /// `{"allOf": [a, b]}` so recursion intersects them; anything else
    /// must be byte-identical or the merge is rejected.
    fn merge_all_of(&mut self, schema: &Value) -> Result<Value, GrammarError> {
        let list = schema
            .get("allOf")
            .and_then(Value::as_array)
            .ok_or_else(|| Self::err("allOf must be an array"))?;
        if list.is_empty() {
            return Err(Self::err("empty allOf"));
        }
        let mut merged = crate::json::Map::new();
        if let Some(o) = schema.as_object() {
            for (k, v) in o.iter() {
                if k != "allOf" {
                    merged.insert(k.clone(), v.clone());
                }
            }
        }
        for branch in list {
            let branch = self.deref_schema(branch, 0)?;
            match branch {
                Value::Bool(true) => continue,
                Value::Bool(false) => return Err(Self::err("allOf branch 'false' matches nothing")),
                Value::Object(bo) => {
                    for (k, v) in bo.iter() {
                        Self::merge_keyword(&mut merged, k, v)?;
                    }
                }
                _ => return Err(Self::err("allOf branch must be an object or boolean")),
            }
        }
        Ok(Value::Object(merged))
    }

    fn merge_keyword(
        merged: &mut crate::json::Map,
        k: &str,
        v: &Value,
    ) -> Result<(), GrammarError> {
        let existing = match merged.get(k) {
            None => {
                merged.insert(k.to_string(), v.clone());
                return Ok(());
            }
            Some(e) => e.clone(),
        };
        let out: Value = match k {
            "type" => {
                let a = Self::type_set(&existing)?;
                let b = Self::type_set(v)?;
                let mut inter: Vec<String> = Vec::new();
                for t in &a {
                    let keep = if b.contains(t) {
                        Some(t.clone())
                    } else if t == "number" && b.iter().any(|x| x == "integer") {
                        Some("integer".to_string())
                    } else if t == "integer" && b.iter().any(|x| x == "number") {
                        Some("integer".to_string())
                    } else {
                        None
                    };
                    if let Some(t) = keep {
                        if !inter.contains(&t) {
                            inter.push(t);
                        }
                    }
                }
                match inter.len() {
                    0 => return Err(Self::err("allOf: contradictory 'type'")),
                    1 => Value::String(inter.pop().unwrap()),
                    _ => Value::Array(inter.into_iter().map(Value::String).collect()),
                }
            }
            "required" => {
                let mut names: Vec<Value> = existing
                    .as_array()
                    .ok_or_else(|| Self::err("'required' must be an array"))?
                    .clone();
                for n in v.as_array().ok_or_else(|| Self::err("'required' must be an array"))? {
                    if !names.contains(n) {
                        names.push(n.clone());
                    }
                }
                Value::Array(names)
            }
            "properties" => {
                let mut props = existing
                    .as_object()
                    .ok_or_else(|| Self::err("'properties' must be an object"))?
                    .clone();
                let new = v
                    .as_object()
                    .ok_or_else(|| Self::err("'properties' must be an object"))?;
                for (name, sub) in new.iter() {
                    let merged_sub = match props.get(name) {
                        None => sub.clone(),
                        Some(old) => {
                            let mut both = crate::json::Map::new();
                            both.insert("allOf", Value::Array(vec![old.clone(), sub.clone()]));
                            Value::Object(both)
                        }
                    };
                    props.insert(name.clone(), merged_sub);
                }
                Value::Object(props)
            }
            "minimum" | "exclusiveMinimum" | "minLength" | "minItems" => {
                let (a, b) = (Self::as_num(&existing, k)?, Self::as_num(v, k)?);
                Value::Number(a.max(b))
            }
            "maximum" | "exclusiveMaximum" | "maxLength" | "maxItems" => {
                let (a, b) = (Self::as_num(&existing, k)?, Self::as_num(v, k)?);
                Value::Number(a.min(b))
            }
            "enum" => {
                let a = existing
                    .as_array()
                    .ok_or_else(|| Self::err("'enum' must be an array"))?;
                let b = v.as_array().ok_or_else(|| Self::err("'enum' must be an array"))?;
                let inter: Vec<Value> = a.iter().filter(|x| b.contains(x)).cloned().collect();
                if inter.is_empty() {
                    return Err(Self::err("allOf: contradictory 'enum'"));
                }
                Value::Array(inter)
            }
            "const" => {
                if existing == *v {
                    existing
                } else {
                    return Err(Self::err("allOf: contradictory 'const'"));
                }
            }
            _ => {
                if existing == *v {
                    existing
                } else {
                    return Err(Self::err(format!("allOf: cannot merge keyword '{k}'")));
                }
            }
        };
        merged.insert(k.to_string(), out);
        Ok(())
    }

    fn type_set(v: &Value) -> Result<Vec<String>, GrammarError> {
        match v {
            Value::String(s) => Ok(vec![s.clone()]),
            Value::Array(a) => a
                .iter()
                .map(|t| {
                    t.as_str()
                        .map(String::from)
                        .ok_or_else(|| Self::err("'type' array entries must be strings"))
                })
                .collect(),
            _ => Err(Self::err("'type' must be a string or array of strings")),
        }
    }

    fn as_num(v: &Value, k: &str) -> Result<f64, GrammarError> {
        v.as_f64()
            .ok_or_else(|| Self::err(format!("'{k}' must be a number")))
    }

    // -- $ref ---------------------------------------------------------------

    fn resolve_ref(&mut self, path: &str) -> Result<usize, GrammarError> {
        if let Some(&idx) = self.refs.get(path) {
            return Ok(idx);
        }
        let target = path
            .strip_prefix("#/$defs/")
            .or_else(|| path.strip_prefix("#/definitions/"))
            .ok_or_else(|| Self::err(format!("unsupported $ref '{path}'")))?;
        let defs = self
            .root_schema
            .get("$defs")
            .or_else(|| self.root_schema.get("definitions"))
            .ok_or_else(|| Self::err("no $defs in schema"))?;
        let sub = defs
            .get(target)
            .ok_or_else(|| Self::err(format!("unresolved $ref '{path}'")))?
            .clone();
        // Pre-register the rule to allow recursion, then fill it.
        let rule = self.g.add_rule(format!("ref:{target}"));
        self.refs.insert(path.to_string(), rule);
        let seq = self.compile(&sub, target)?;
        self.g.add_alt(rule, seq);
        Ok(rule)
    }

    // -- strings ------------------------------------------------------------

    fn string_schema(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let pattern = schema.get("pattern").and_then(Value::as_str);
        let format = schema.get("format").and_then(Value::as_str);
        let min_len = schema.get("minLength").and_then(Value::as_usize);
        let max_len = schema.get("maxLength").and_then(Value::as_usize);

        let effective = match (pattern, format) {
            (Some(_), Some(_)) => {
                return Err(Self::err("'pattern' and 'format' cannot be combined"))
            }
            (Some(p), None) => Some(p),
            // Unknown formats are annotations (spec default); known ones
            // compile as anchored byte grammars.
            (None, Some(f)) => format_pattern(f),
            (None, None) => None,
        };
        if let Some(p) = effective {
            if min_len.is_some() || max_len.is_some() {
                return Err(Self::err(
                    "'pattern'/'format' cannot be combined with length bounds",
                ));
            }
            let mut seq = Grammar::lit(b"\"");
            seq.extend(regex::compile_fragment(&mut self.g, p, hint)?);
            seq.extend(Grammar::lit(b"\""));
            return Ok(seq);
        }
        if min_len.is_none() && max_len.is_none() {
            return Ok(vec![Sym::Ref(self.string_rule())]);
        }
        let min = min_len.unwrap_or(0);
        if min > MAX_STRING_LEN || max_len.map_or(false, |m| m > MAX_STRING_LEN) {
            return Err(Self::err(format!("string length bound exceeds {MAX_STRING_LEN}")));
        }
        if let Some(max) = max_len {
            if max < min {
                return Err(Self::err("maxLength < minLength"));
            }
        }
        // One grammar char = one escaped or unescaped code point. (Code
        // points above the BMP count 1 here but 2 in UTF-16-centric
        // validators; the canon avoids surrogate-pair escapes.)
        let ch = self.char_rule();
        let mut seq = Grammar::lit(b"\"");
        seq.extend(self.g.repeat(vec![Sym::Ref(ch)], min, max_len, hint));
        seq.extend(Grammar::lit(b"\""));
        Ok(seq)
    }

    // -- numbers ------------------------------------------------------------

    fn raw_bounds(&self, schema: &Value) -> Result<RawBounds, GrammarError> {
        let mut rb = RawBounds::default();
        for (key, slot) in [
            ("minimum", 0usize),
            ("exclusiveMinimum", 1),
            ("maximum", 2),
            ("exclusiveMaximum", 3),
        ] {
            if let Some(v) = schema.get(key) {
                let n = v.as_f64().ok_or_else(|| {
                    Self::err(format!(
                        "'{key}' must be a number (draft-4 boolean form unsupported)"
                    ))
                })?;
                if !n.is_finite() || n.abs() > MAX_ABS_BOUND {
                    return Err(Self::err(format!("'{key}' out of supported range")));
                }
                match slot {
                    0 => rb.min = Some(n),
                    1 => rb.emin = Some(n),
                    2 => rb.max = Some(n),
                    _ => rb.emax = Some(n),
                }
            }
        }
        Ok(rb)
    }

    fn integer_schema(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let rb = self.raw_bounds(schema)?;
        if !rb.any() {
            return Ok(vec![Sym::Ref(self.integer_rule())]);
        }
        // Effective inclusive integer bounds (non-integral bounds round
        // inward; integral exclusive bounds step by one).
        let lo_c = rb.min.map(|m| m.ceil() as i64);
        let lo_e = rb.emin.map(|m| if m.fract() == 0.0 { m as i64 + 1 } else { m.ceil() as i64 });
        let li = match (lo_c, lo_e) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
        let hi_f = rb.max.map(|m| m.floor() as i64);
        let hi_e = rb.emax.map(|m| if m.fract() == 0.0 { m as i64 - 1 } else { m.floor() as i64 });
        let ui = match (hi_f, hi_e) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        if let (Some(a), Some(b)) = (li, ui) {
            if a > b {
                return Err(Self::err("contradictory numeric bounds"));
            }
        }
        self.int_range_syms(li, ui, hint)
    }

    /// The canonical integers in `[lo, hi]` (either side may be open) as
    /// a digit-DFA symbol sequence: sign split + per-digit-length range
    /// decomposition. No leading zeros, no `-0`.
    fn int_range_syms(
        &mut self,
        lo: Option<i64>,
        hi: Option<i64>,
        hint: &str,
    ) -> Result<Vec<Sym>, GrammarError> {
        if lo.is_none() && hi.is_none() {
            return Ok(vec![Sym::Ref(self.integer_rule())]);
        }
        if let (Some(l), Some(h)) = (lo, hi) {
            if l > h {
                return Err(Self::err("contradictory numeric bounds"));
            }
        }
        let mut alts: Vec<Vec<Sym>> = Vec::new();
        // Negative side: magnitudes m with -m in [lo, min(hi, -1)].
        if lo.map_or(true, |l| l < 0) {
            let m_min = match hi {
                Some(h) if h < 0 => (-h) as u64,
                _ => 1,
            };
            let m_max = lo.map(|l| (-l) as u64);
            if m_max.map_or(true, |mm| m_min <= mm) {
                for alt in pos_range_alts(&mut self.g, m_min, m_max, hint) {
                    let mut seq = vec![Sym::Class(ByteClass::byte(b'-'))];
                    seq.extend(alt);
                    alts.push(seq);
                }
            }
        }
        // Non-negative side.
        if hi.map_or(true, |h| h >= 0) {
            let a = lo.map_or(0, |l| l.max(0)) as u64;
            let b = hi.map(|h| h as u64);
            alts.extend(pos_range_alts(&mut self.g, a, b, hint));
        }
        if alts.is_empty() {
            return Err(Self::err("contradictory numeric bounds"));
        }
        Ok(wrap_alts(&mut self.g, alts, hint))
    }

    /// Bounded `number`: integer literals in range, plus decimal forms
    /// `n.digits` whose whole unit interval fits the bounds, plus
    /// nonzero-fraction forms hugging an exclusive integral bound. Bounds
    /// must be integral (a structured error otherwise); exponents are not
    /// part of the bounded canon.
    fn number_schema(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let rb = self.raw_bounds(schema)?;
        if !rb.any() {
            return Ok(vec![Sym::Ref(self.number_rule())]);
        }
        for v in [rb.min, rb.emin, rb.max, rb.emax].iter().flatten() {
            if v.fract() != 0.0 {
                return Err(Self::err(
                    "non-integral bounds on type 'number' unsupported (use integral bounds)",
                ));
            }
        }
        // Strictest lower/upper as (value, exclusive); ties prefer the
        // exclusive form.
        let lo: Option<(i64, bool)> = match (rb.min.map(|v| v as i64), rb.emin.map(|v| v as i64)) {
            (None, None) => None,
            (Some(a), None) => Some((a, false)),
            (None, Some(b)) => Some((b, true)),
            (Some(a), Some(b)) => Some(if b >= a { (b, true) } else { (a, false) }),
        };
        let hi: Option<(i64, bool)> = match (rb.max.map(|v| v as i64), rb.emax.map(|v| v as i64)) {
            (None, None) => None,
            (Some(a), None) => Some((a, false)),
            (None, Some(b)) => Some((b, true)),
            (Some(a), Some(b)) => Some(if b <= a { (b, true) } else { (a, false) }),
        };
        // Inclusive integer attainment bounds.
        let li = lo.map(|(l, ex)| if ex { l + 1 } else { l });
        let ui = hi.map(|(h, ex)| if ex { h - 1 } else { h });

        let digits1 = self.digits1_rule();
        let nonzero = self.nonzero_frac_rule();
        let mut alts: Vec<Vec<Sym>> = Vec::new();

        // 1. Integer literals.
        let int_ok = match (li, ui) {
            (Some(a), Some(b)) => a <= b,
            _ => true,
        };
        if int_ok {
            alts.push(self.int_range_syms(li, ui, hint)?);
        }
        // 2. Non-negative decimals n.f with [n, n+1) inside the bounds:
        //    n >= max(li, 0) and n + 1 <= hi-value.
        {
            let a = li.map_or(0, |l| l.max(0));
            let b = hi.map(|(h, _)| h - 1);
            if b.map_or(true, |b| a <= b) {
                let pr = pos_range_alts(&mut self.g, a as u64, b.map(|b| b as u64), hint);
                let mut seq = wrap_alts(&mut self.g, pr, hint);
                seq.push(Sym::Class(ByteClass::byte(b'.')));
                seq.push(Sym::Ref(digits1));
                alts.push(seq);
            }
        }
        // 3. Negative decimals -m.f with (-(m+1), -m] inside the bounds:
        //    -m <= ui and m + 1 <= -lo-value.
        {
            let m_min = match ui {
                Some(u) if u < 0 => -u,
                _ => 0,
            };
            let m_max = lo.map(|(l, _)| -l - 1);
            if m_max.map_or(true, |mm| m_min <= mm) && lo.map_or(true, |(l, _)| l <= -1) {
                let pr =
                    pos_range_alts(&mut self.g, m_min as u64, m_max.map(|m| m as u64), hint);
                let mut seq = vec![Sym::Class(ByteClass::byte(b'-'))];
                seq.extend(wrap_alts(&mut self.g, pr, hint));
                seq.push(Sym::Class(ByteClass::byte(b'.')));
                seq.push(Sym::Ref(digits1));
                alts.push(seq);
            }
        }
        // 4. Exclusive lower bound l >= 0: "l." nonzero-fraction lies in
        //    (l, l+1).
        if let Some((l, true)) = lo {
            if l >= 0 && hi.map_or(true, |(h, _)| l + 1 <= h) {
                let mut seq = Grammar::lit(l.to_string().as_bytes());
                seq.push(Sym::Class(ByteClass::byte(b'.')));
                seq.push(Sym::Ref(nonzero));
                alts.push(seq);
            }
        }
        // 5. Exclusive upper bound h <= 0: "-|h|." nonzero-fraction lies
        //    in (h-1, h).
        if let Some((h, true)) = hi {
            if h <= 0 && lo.map_or(true, |(l, _)| l <= h - 1) {
                let mut seq = Grammar::lit(b"-");
                seq.extend(Grammar::lit((-h).to_string().as_bytes()));
                seq.push(Sym::Class(ByteClass::byte(b'.')));
                seq.push(Sym::Ref(nonzero));
                alts.push(seq);
            }
        }
        if alts.is_empty() {
            return Err(Self::err("contradictory numeric bounds"));
        }
        Ok(wrap_alts(&mut self.g, alts, hint))
    }

    // -- objects ------------------------------------------------------------

    fn object_rule(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let empty = crate::json::Map::new();
        let props = schema
            .get("properties")
            .and_then(Value::as_object)
            .unwrap_or(&empty)
            .clone();
        let required: Vec<String> = schema
            .get("required")
            .and_then(Value::as_array)
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        for r in &required {
            if !props.contains_key(r) {
                return Err(Self::err(format!("required property '{r}' not declared")));
            }
        }
        let addl = schema.get("additionalProperties");

        if props.is_empty() {
            return match addl {
                // {"type":"object"} / additionalProperties:true -> any object.
                None | Some(Value::Bool(true)) => Ok(vec![Sym::Ref(self.any_object())]),
                // No properties at all: only the empty object.
                Some(Value::Bool(false)) => Ok(Grammar::lit(b"{}")),
                // Typed map: { "k": V, ... } with free string keys.
                Some(sub) => self.map_rule(sub, hint),
            };
        }
        match addl {
            None | Some(Value::Bool(false)) => {}
            Some(_) => {
                return Err(Self::err(
                    "additionalProperties alongside declared properties unsupported \
                     (the grammar cannot distinguish extra keys from declared ones)",
                ))
            }
        }

        // Compile each property's value grammar + its `"name":` prefix.
        struct Prop {
            prefix: Vec<u8>,
            value: Vec<Sym>,
            required: bool,
        }
        let mut plist: Vec<Prop> = Vec::new();
        for (name, sub) in props.iter() {
            let mut prefix = crate::json::to_string(&Value::String(name.clone())).into_bytes();
            prefix.push(b':');
            plist.push(Prop {
                prefix,
                value: self.compile(sub, &format!("{hint}.{name}"))?,
                required: required.iter().any(|r| r == name),
            });
        }

        // members(i, first): the tail of the member list starting at
        // property i, knowing whether a member was already emitted.
        // Built back-to-front; at most 2 rules per property.
        let n = plist.len();
        let mut memo: HashMap<(usize, bool), usize> = HashMap::new();
        for i in (0..n).rev() {
            for &first in &[false, true] {
                let suffix = if first { "F" } else { "" };
                let rule = self.g.add_rule(format!("{hint}.members{i}{suffix}"));
                memo.insert((i, first), rule);
            }
        }
        // Fill alternatives (memo ids already fixed).
        for i in (0..n).rev() {
            for &first in &[false, true] {
                let rule = memo[&(i, first)];
                let tail: Vec<Sym> = if i + 1 < n {
                    vec![Sym::Ref(memo[&(i + 1, false)])]
                } else {
                    Vec::new()
                };
                let tail_skip: Vec<Sym> = if i + 1 < n {
                    vec![Sym::Ref(memo[&(i + 1, first)])]
                } else {
                    Vec::new()
                };
                // emit property i
                let mut alt = Vec::new();
                if !first {
                    alt.extend(Grammar::lit(b","));
                }
                alt.extend(Grammar::lit(&plist[i].prefix));
                alt.extend(plist[i].value.clone());
                alt.extend(tail);
                self.g.add_alt(rule, alt);
                // or skip it, when optional
                if !plist[i].required {
                    self.g.add_alt(rule, tail_skip);
                }
            }
        }

        let mut seq = Grammar::lit(b"{");
        seq.push(Sym::Ref(memo[&(0, true)]));
        seq.extend(Grammar::lit(b"}"));
        Ok(seq)
    }

    /// `{}` | `{"k":V(,"k":V)*}` — free string keys, typed values.
    fn map_rule(&mut self, value_schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let key = self.string_rule();
        let val = self.compile(value_schema, &format!("{hint}.additional"))?;
        let member = self.g.add_rule(format!("{hint}.map-member"));
        let mut m = vec![Sym::Ref(key)];
        m.extend(Grammar::lit(b":"));
        m.extend(val);
        self.g.add_alt(member, m);
        let mut rep = Grammar::lit(b",");
        rep.push(Sym::Ref(member));
        let more = self.g.star(rep, hint);
        let inner = self.g.opt(vec![Sym::Ref(member), more], hint);
        let mut seq = Grammar::lit(b"{");
        seq.push(inner);
        seq.extend(Grammar::lit(b"}"));
        Ok(seq)
    }

    // -- arrays -------------------------------------------------------------

    fn array_rule(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let mut prefix: Vec<Vec<Sym>> = Vec::new();
        if let Some(p) = schema.get("prefixItems") {
            let list = p
                .as_array()
                .ok_or_else(|| Self::err("'prefixItems' must be an array"))?;
            if list.len() > MAX_ARRAY_ITEMS {
                return Err(Self::err(format!("prefixItems > {MAX_ARRAY_ITEMS} unsupported")));
            }
            for (i, s) in list.iter().enumerate() {
                prefix.push(self.compile(s, &format!("{hint}.prefix{i}"))?);
            }
        }
        let k = prefix.len();
        let items_false = matches!(schema.get("items"), Some(Value::Bool(false)));
        let item = match schema.get("items") {
            Some(Value::Bool(false)) => Vec::new(), // never referenced
            Some(s) => self.compile(s, &format!("{hint}.items"))?,
            None => vec![Sym::Ref(self.any_value())],
        };
        let min = schema.get("minItems").and_then(Value::as_usize).unwrap_or(0);
        let mut max = schema.get("maxItems").and_then(Value::as_usize);
        if items_false {
            // items:false forbids elements beyond the prefix.
            max = Some(max.map_or(k, |m| m.min(k)));
        }
        if let Some(max) = max {
            if max < min {
                return Err(Self::err("maxItems < minItems"));
            }
            if max > MAX_ARRAY_ITEMS {
                return Err(Self::err(format!("maxItems > {MAX_ARRAY_ITEMS} unsupported")));
            }
        }
        if min > MAX_ARRAY_ITEMS {
            return Err(Self::err(format!("minItems > {MAX_ARRAY_ITEMS} unsupported")));
        }

        let mut seq = Grammar::lit(b"[");
        match max {
            Some(max) => {
                for i in 0..min {
                    if i > 0 {
                        seq.extend(Grammar::lit(b","));
                    }
                    let it = if i < k { prefix[i].clone() } else { item.clone() };
                    seq.extend(it);
                }
                // Optional tail built back-to-front so commas nest
                // correctly: (,item (,item ...)?)? — never "[,x]".
                let mut tail: Option<Sym> = None;
                for i in (min..max).rev() {
                    let mut inner = Vec::new();
                    if i > 0 {
                        inner.extend(Grammar::lit(b","));
                    }
                    let it = if i < k { prefix[i].clone() } else { item.clone() };
                    inner.extend(it);
                    if let Some(t) = tail.take() {
                        inner.push(t);
                    }
                    tail = Some(self.g.opt(inner, hint));
                }
                if let Some(t) = tail {
                    seq.push(t);
                }
            }
            None => {
                for i in 0..min {
                    if i > 0 {
                        seq.extend(Grammar::lit(b","));
                    }
                    let it = if i < k { prefix[i].clone() } else { item.clone() };
                    seq.extend(it);
                }
                let mut rep = Grammar::lit(b",");
                rep.extend(item.clone());
                let star = self.g.star(rep, hint);
                if k > min {
                    // Prefix items min..k are optional but positional; the
                    // unbounded `items` tail only opens past the prefix.
                    let mut tail: Sym = star;
                    for i in (min..k).rev() {
                        let mut inner = Vec::new();
                        if i > 0 {
                            inner.extend(Grammar::lit(b","));
                        }
                        inner.extend(prefix[i].clone());
                        inner.push(tail);
                        tail = self.g.opt(inner, hint);
                    }
                    seq.push(tail);
                } else if min == 0 {
                    // [ (item ("," item)*)? ]
                    let mut inner = item;
                    inner.push(star);
                    seq.push(self.g.opt(inner, hint));
                } else {
                    seq.push(star);
                }
            }
        }
        seq.extend(Grammar::lit(b"]"));
        Ok(seq)
    }

    // -- shared primitive rules ---------------------------------------------

    fn shared_rule(
        &mut self,
        name: &'static str,
        build: impl FnOnce(&mut Grammar, usize),
    ) -> usize {
        if let Some(&r) = self.shared.get(name) {
            return r;
        }
        let r = self.g.add_rule(name);
        self.shared.insert(name, r);
        build(&mut self.g, r);
        r
    }

    /// One JSON string character: a valid UTF-8 sequence (surrogate range
    /// excluded, so byte-level token masking can never strand a partial
    /// character — the same treatment XGrammar applies) or an escape.
    /// Counts as one code point for length-bounded strings.
    fn char_rule(&mut self) -> usize {
        if let Some(&r) = self.shared.get("json-char") {
            return r;
        }
        let r = self.g.add_rule("json-char");
        self.shared.insert("json-char", r);
        let g = &mut self.g;
        let cls = |ranges: Vec<(u8, u8)>| Sym::Class(ByteClass { ranges, negated: false });
        let cont = || cls(vec![(0x80, 0xBF)]);
        // ASCII printable minus quote/backslash.
        let ascii = cls(vec![(0x20, 0x21), (0x23, 0x5B), (0x5D, 0x7F)]);
        let utf8 = g.add_rule("json-utf8-char");
        g.add_alt(utf8, vec![ascii]);
        g.add_alt(utf8, vec![cls(vec![(0xC2, 0xDF)]), cont()]);
        g.add_alt(utf8, vec![cls(vec![(0xE0, 0xE0)]), cls(vec![(0xA0, 0xBF)]), cont()]);
        g.add_alt(utf8, vec![cls(vec![(0xE1, 0xEC), (0xEE, 0xEF)]), cont(), cont()]);
        g.add_alt(utf8, vec![cls(vec![(0xED, 0xED)]), cls(vec![(0x80, 0x9F)]), cont()]);
        g.add_alt(utf8, vec![cls(vec![(0xF0, 0xF0)]), cls(vec![(0x90, 0xBF)]), cont(), cont()]);
        g.add_alt(utf8, vec![cls(vec![(0xF1, 0xF3)]), cont(), cont(), cont()]);
        g.add_alt(utf8, vec![cls(vec![(0xF4, 0xF4)]), cls(vec![(0x80, 0x8F)]), cont(), cont()]);
        let esc_simple = Sym::Class(ByteClass {
            ranges: [b'"', b'\\', b'/', b'b', b'f', b'n', b'r', b't']
                .iter()
                .map(|&c| (c, c))
                .collect(),
            negated: false,
        });
        let hex = || {
            Sym::Class(ByteClass {
                ranges: vec![(b'0', b'9'), (b'a', b'f'), (b'A', b'F')],
                negated: false,
            })
        };
        let esc_alt = g.add_rule("json-escape");
        g.add_alt(esc_alt, vec![esc_simple]);
        g.add_alt(
            esc_alt,
            vec![Sym::Class(ByteClass::byte(b'u')), hex(), hex(), hex(), hex()],
        );
        g.add_alt(r, vec![Sym::Ref(utf8)]);
        g.add_alt(r, vec![Sym::Class(ByteClass::byte(b'\\')), Sym::Ref(esc_alt)]);
        r
    }

    /// JSON string: `"` char* `"`.
    fn string_rule(&mut self) -> usize {
        if let Some(&r) = self.shared.get("json-string") {
            return r;
        }
        let ch = self.char_rule();
        let r = self.g.add_rule("json-string");
        self.shared.insert("json-string", r);
        let chars = self.g.add_rule("json-string-chars");
        self.g.add_alt(chars, Vec::new());
        self.g.add_alt(chars, vec![Sym::Ref(ch), Sym::Ref(chars)]);
        let mut alt = Grammar::lit(b"\"");
        alt.push(Sym::Ref(chars));
        alt.extend(Grammar::lit(b"\""));
        self.g.add_alt(r, alt);
        r
    }

    /// `[0-9]+`
    fn digits1_rule(&mut self) -> usize {
        self.shared_rule("digits1", |g, r| {
            g.add_alt(r, vec![digit(b'0', b'9')]);
            g.add_alt(r, vec![digit(b'0', b'9'), Sym::Ref(r)]);
        })
    }

    /// Fraction digits with at least one nonzero: `0* [1-9] [0-9]*`.
    fn nonzero_frac_rule(&mut self) -> usize {
        self.shared_rule("frac-nonzero", |g, r| {
            let zeros = g.star(vec![digit(b'0', b'0')], "frac-nonzero");
            let rest = g.star(vec![digit(b'0', b'9')], "frac-nonzero");
            g.add_alt(r, vec![zeros, digit(b'1', b'9'), rest]);
        })
    }

    /// JSON number.
    fn number_rule(&mut self) -> usize {
        let int = self.integer_rule();
        self.shared_rule("json-number", |g, r| {
            // frac := "." [0-9]+ ; exp := [eE] [+-]? [0-9]+
            let digits1 = {
                let d = g.add_rule("digits");
                g.add_alt(d, vec![digit(b'0', b'9')]);
                g.add_alt(d, vec![digit(b'0', b'9'), Sym::Ref(d)]);
                d
            };
            let frac = g.add_rule("frac?");
            g.add_alt(frac, Vec::new());
            g.add_alt(frac, {
                let mut v = Grammar::lit(b".");
                v.push(Sym::Ref(digits1));
                v
            });
            let exp = g.add_rule("exp?");
            g.add_alt(exp, Vec::new());
            {
                let e_ranges = vec![(b'e', b'e'), (b'E', b'E')];
                let e = Sym::Class(ByteClass { ranges: e_ranges, negated: false });
                let sign = g.add_rule("sign?");
                g.add_alt(sign, Vec::new());
                let signs = ByteClass { ranges: vec![(b'+', b'+'), (b'-', b'-')], negated: false };
                g.add_alt(sign, vec![Sym::Class(signs)]);
                g.add_alt(exp, vec![e, Sym::Ref(sign), Sym::Ref(digits1)]);
            }
            g.add_alt(r, vec![Sym::Ref(int), Sym::Ref(frac), Sym::Ref(exp)]);
        })
    }

    /// JSON integer: -? (0 | [1-9][0-9]*)
    fn integer_rule(&mut self) -> usize {
        self.shared_rule("json-integer", |g, r| {
            let neg = g.add_rule("neg?");
            g.add_alt(neg, Vec::new());
            g.add_alt(neg, Grammar::lit(b"-"));
            let nz = digit(b'1', b'9');
            let d0 = g.add_rule("digits*");
            g.add_alt(d0, Vec::new());
            g.add_alt(d0, vec![digit(b'0', b'9'), Sym::Ref(d0)]);
            g.add_alt(r, vec![Sym::Ref(neg), Sym::Class(ByteClass::byte(b'0'))]);
            g.add_alt(r, vec![Sym::Ref(neg), nz, Sym::Ref(d0)]);
        })
    }

    /// Any JSON value (compact form).
    fn any_value(&mut self) -> usize {
        if let Some(&r) = self.shared.get("json-value") {
            return r;
        }
        let r = self.g.add_rule("json-value");
        self.shared.insert("json-value", r);
        let string = self.string_rule();
        let number = self.number_rule();
        let object = self.any_object_inner(r);
        let array = self.any_array_inner(r);
        self.g.add_alt(r, vec![Sym::Ref(string)]);
        self.g.add_alt(r, vec![Sym::Ref(number)]);
        self.g.add_alt(r, vec![Sym::Ref(object)]);
        self.g.add_alt(r, vec![Sym::Ref(array)]);
        self.g.add_alt(r, Grammar::lit(b"true"));
        self.g.add_alt(r, Grammar::lit(b"false"));
        self.g.add_alt(r, Grammar::lit(b"null"));
        r
    }

    fn any_object(&mut self) -> usize {
        let value = self.any_value();
        self.any_object_inner(value)
    }

    fn any_object_inner(&mut self, value: usize) -> usize {
        if let Some(&r) = self.shared.get("json-object") {
            return r;
        }
        let r = self.g.add_rule("json-object");
        self.shared.insert("json-object", r);
        let string = self.string_rule();
        // member := string ":" value ; obj := "{" (member ("," member)*)? "}"
        let member = self.g.add_rule("json-member");
        let mut m = vec![Sym::Ref(string)];
        m.extend(Grammar::lit(b":"));
        m.push(Sym::Ref(value));
        self.g.add_alt(member, m);
        let mut rep = Grammar::lit(b",");
        rep.push(Sym::Ref(member));
        let more = self.g.star(rep, "json-object");
        let inner = self.g.opt(vec![Sym::Ref(member), more], "json-object");
        let mut alt = Grammar::lit(b"{");
        alt.push(inner);
        alt.extend(Grammar::lit(b"}"));
        self.g.add_alt(r, alt);
        r
    }

    fn any_array_inner(&mut self, value: usize) -> usize {
        if let Some(&r) = self.shared.get("json-array") {
            return r;
        }
        let r = self.g.add_rule("json-array");
        self.shared.insert("json-array", r);
        let mut rep = Grammar::lit(b",");
        rep.push(Sym::Ref(value));
        let more = self.g.star(rep, "json-array");
        let inner = self.g.opt(vec![Sym::Ref(value), more], "json-array");
        let mut alt = Grammar::lit(b"[");
        alt.push(inner);
        alt.extend(Grammar::lit(b"]"));
        self.g.add_alt(r, alt);
        r
    }
}
