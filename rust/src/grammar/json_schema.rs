//! JSON Schema -> grammar compiler (the `response_format: json_schema`
//! path of the OpenAI-style API, WebLLM §2.1).
//!
//! Supported subset (documented in DESIGN.md): object/properties/required
//! (additionalProperties treated as false), string, number, integer,
//! boolean, null, enum (scalars), const, array/items/minItems/maxItems,
//! anyOf/oneOf, $ref into #/$defs or #/definitions (recursion allowed),
//! and the empty schema (any JSON value).
//!
//! Emitted JSON is **compact** (no inter-token whitespace) — the same
//! canonicalization XGrammar defaults to; it keeps token masks tight.

use super::grammar::{ByteClass, Grammar, GrammarError, Sym};
use crate::json::Value;
use std::collections::HashMap;

/// Compile a JSON Schema (as a parsed [`Value`]) into a byte-level
/// [`Grammar`] matching its *compact* JSON serialization.
///
/// # Examples
///
/// ```
/// use std::rc::Rc;
/// use webllm::grammar::{schema_to_grammar, GrammarMatcher};
/// use webllm::json::parse;
///
/// let schema = parse(r#"{
///     "type": "object",
///     "properties": {"ok": {"type": "boolean"}},
///     "required": ["ok"]
/// }"#).unwrap();
/// let g = Rc::new(schema_to_grammar(&schema).unwrap());
///
/// let mut m = GrammarMatcher::new(g.clone());
/// assert!(m.advance_bytes(br#"{"ok":true}"#) && m.is_accepting());
///
/// // The canon is compact: whitespace is not part of the language.
/// let mut m = GrammarMatcher::new(g);
/// assert!(!m.advance_bytes(br#"{ "ok": true }"#));
/// ```
///
/// The empty schema (`{}`) matches any JSON value; unsupported keywords
/// produce [`GrammarError::Schema`](super::GrammarError::Schema).
pub fn schema_to_grammar(schema: &Value) -> Result<Grammar, GrammarError> {
    let mut c = Compiler {
        g: Grammar::new(),
        root_schema: schema,
        refs: HashMap::new(),
        shared: HashMap::new(),
    };
    let root = c.g.add_rule("root");
    debug_assert_eq!(root, 0);
    let seq = c.compile(schema, "root")?;
    c.g.add_alt(0, seq);
    c.g.validate()?;
    Ok(c.g)
}

struct Compiler<'a> {
    g: Grammar,
    root_schema: &'a Value,
    /// $ref path -> rule index (memoized; enables recursive schemas).
    refs: HashMap<String, usize>,
    /// Shared primitive rules ("string", "number", ...) by name.
    shared: HashMap<&'static str, usize>,
}

impl<'a> Compiler<'a> {
    fn err(m: impl Into<String>) -> GrammarError {
        GrammarError::Schema(m.into())
    }

    fn compile(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        match schema {
            // `true` / `{}` -> any JSON value.
            Value::Bool(true) => Ok(vec![Sym::Ref(self.any_value())]),
            Value::Bool(false) => Err(Self::err("schema 'false' matches nothing")),
            Value::Object(o) if o.is_empty() => Ok(vec![Sym::Ref(self.any_value())]),
            Value::Object(_) => self.compile_object_schema(schema, hint),
            _ => Err(Self::err("schema must be an object or boolean")),
        }
    }

    fn compile_object_schema(
        &mut self,
        schema: &Value,
        hint: &str,
    ) -> Result<Vec<Sym>, GrammarError> {
        if let Some(r) = schema.get("$ref").and_then(Value::as_str) {
            return Ok(vec![Sym::Ref(self.resolve_ref(r)?)]);
        }
        if let Some(c) = schema.get("const") {
            return Ok(Grammar::lit(crate::json::to_string(c).as_bytes()));
        }
        if let Some(e) = schema.get("enum").and_then(Value::as_array) {
            let alts: Vec<Vec<Sym>> = e
                .iter()
                .map(|v| Grammar::lit(crate::json::to_string(v).as_bytes()))
                .collect();
            if alts.is_empty() {
                return Err(Self::err("empty enum"));
            }
            return Ok(vec![self.g.choice(alts, hint)]);
        }
        for key in ["anyOf", "oneOf"] {
            if let Some(list) = schema.get(key).and_then(Value::as_array) {
                let mut alts = Vec::new();
                for (i, s) in list.iter().enumerate() {
                    alts.push(self.compile(s, &format!("{hint}.{key}{i}"))?);
                }
                if alts.is_empty() {
                    return Err(Self::err(format!("empty {key}")));
                }
                return Ok(vec![self.g.choice(alts, hint)]);
            }
        }

        match schema.get("type").and_then(Value::as_str) {
            Some("string") => Ok(vec![Sym::Ref(self.string_rule())]),
            Some("number") => Ok(vec![Sym::Ref(self.number_rule())]),
            Some("integer") => Ok(vec![Sym::Ref(self.integer_rule())]),
            Some("boolean") => {
                Ok(vec![self.g.choice(
                    vec![Grammar::lit(b"true"), Grammar::lit(b"false")],
                    hint,
                )])
            }
            Some("null") => Ok(Grammar::lit(b"null")),
            Some("object") => self.object_rule(schema, hint),
            Some("array") => self.array_rule(schema, hint),
            Some(other) => Err(Self::err(format!("unsupported type '{other}'"))),
            None => Ok(vec![Sym::Ref(self.any_value())]),
        }
    }

    fn resolve_ref(&mut self, path: &str) -> Result<usize, GrammarError> {
        if let Some(&idx) = self.refs.get(path) {
            return Ok(idx);
        }
        let target = path
            .strip_prefix("#/$defs/")
            .or_else(|| path.strip_prefix("#/definitions/"))
            .ok_or_else(|| Self::err(format!("unsupported $ref '{path}'")))?;
        let defs = self
            .root_schema
            .get("$defs")
            .or_else(|| self.root_schema.get("definitions"))
            .ok_or_else(|| Self::err("no $defs in schema"))?;
        let sub = defs
            .get(target)
            .ok_or_else(|| Self::err(format!("unresolved $ref '{path}'")))?
            .clone();
        // Pre-register the rule to allow recursion, then fill it.
        let rule = self.g.add_rule(format!("ref:{target}"));
        self.refs.insert(path.to_string(), rule);
        let seq = self.compile(&sub, target)?;
        self.g.add_alt(rule, seq);
        Ok(rule)
    }

    fn object_rule(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let empty = crate::json::Map::new();
        let props = schema
            .get("properties")
            .and_then(Value::as_object)
            .unwrap_or(&empty)
            .clone();
        let required: Vec<String> = schema
            .get("required")
            .and_then(Value::as_array)
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        for r in &required {
            if !props.contains_key(r) {
                return Err(Self::err(format!("required property '{r}' not declared")));
            }
        }

        if props.is_empty() {
            // {"type":"object"} with no properties -> any object.
            return Ok(vec![Sym::Ref(self.any_object())]);
        }

        // Compile each property's value grammar + its `"name":` prefix.
        struct Prop {
            prefix: Vec<u8>,
            value: Vec<Sym>,
            required: bool,
        }
        let mut plist: Vec<Prop> = Vec::new();
        for (name, sub) in props.iter() {
            let mut prefix = crate::json::to_string(&Value::String(name.clone())).into_bytes();
            prefix.push(b':');
            plist.push(Prop {
                prefix,
                value: self.compile(sub, &format!("{hint}.{name}"))?,
                required: required.iter().any(|r| r == name),
            });
        }

        // members(i, first): the tail of the member list starting at
        // property i, knowing whether a member was already emitted.
        // Built back-to-front; at most 2 rules per property.
        let n = plist.len();
        let mut memo: HashMap<(usize, bool), usize> = HashMap::new();
        for i in (0..n).rev() {
            for &first in &[false, true] {
                let rule = self.g.add_rule(format!("{hint}.members{i}{}", if first { "F" } else { "" }));
                memo.insert((i, first), rule);
            }
        }
        // Fill alternatives (memo ids already fixed).
        for i in (0..n).rev() {
            for &first in &[false, true] {
                let rule = memo[&(i, first)];
                let tail: Vec<Sym> = if i + 1 < n {
                    vec![Sym::Ref(memo[&(i + 1, false)])]
                } else {
                    Vec::new()
                };
                let tail_skip: Vec<Sym> = if i + 1 < n {
                    vec![Sym::Ref(memo[&(i + 1, first)])]
                } else {
                    Vec::new()
                };
                // emit property i
                let mut alt = Vec::new();
                if !first {
                    alt.extend(Grammar::lit(b","));
                }
                alt.extend(Grammar::lit(&plist[i].prefix));
                alt.extend(plist[i].value.clone());
                alt.extend(tail);
                self.g.add_alt(rule, alt);
                // or skip it, when optional
                if !plist[i].required {
                    self.g.add_alt(rule, tail_skip);
                }
            }
        }

        let mut seq = Grammar::lit(b"{");
        seq.push(Sym::Ref(memo[&(0, true)]));
        seq.extend(Grammar::lit(b"}"));
        Ok(seq)
    }

    fn array_rule(&mut self, schema: &Value, hint: &str) -> Result<Vec<Sym>, GrammarError> {
        let item = match schema.get("items") {
            Some(s) => self.compile(s, &format!("{hint}.items"))?,
            None => vec![Sym::Ref(self.any_value())],
        };
        let min = schema.get("minItems").and_then(Value::as_usize).unwrap_or(0);
        let max = schema.get("maxItems").and_then(Value::as_usize);
        if let Some(max) = max {
            if max < min {
                return Err(Self::err("maxItems < minItems"));
            }
            if max > 64 {
                return Err(Self::err("maxItems > 64 unsupported"));
            }
        }

        let mut seq = Grammar::lit(b"[");
        match (min, max) {
            (0, None) => {
                // [ (item ("," item)*)? ]
                let mut rep = Grammar::lit(b",");
                rep.extend(item.clone());
                let more = self.g.star(rep, hint);
                let mut inner = item;
                inner.push(more);
                seq.push(self.g.opt(inner, hint));
            }
            (min, None) => {
                for i in 0..min {
                    if i > 0 {
                        seq.extend(Grammar::lit(b","));
                    }
                    seq.extend(item.clone());
                }
                let mut rep = Grammar::lit(b",");
                rep.extend(item.clone());
                seq.push(self.g.star(rep, hint));
            }
            (min, Some(max)) => {
                for i in 0..min {
                    if i > 0 {
                        seq.extend(Grammar::lit(b","));
                    }
                    seq.extend(item.clone());
                }
                // Optional tail built back-to-front so commas nest
                // correctly: (,item (,item ...)?)? — never "[,x]".
                let mut tail: Option<Sym> = None;
                for i in (min..max).rev() {
                    let mut inner = Vec::new();
                    if i > 0 {
                        inner.extend(Grammar::lit(b","));
                    }
                    inner.extend(item.clone());
                    if let Some(t) = tail.take() {
                        inner.push(t);
                    }
                    tail = Some(self.g.opt(inner, hint));
                }
                if let Some(t) = tail {
                    seq.push(t);
                }
            }
        }
        seq.extend(Grammar::lit(b"]"));
        Ok(seq)
    }

    // -- shared primitive rules ---------------------------------------------

    fn shared_rule(&mut self, name: &'static str, build: impl FnOnce(&mut Grammar, usize)) -> usize {
        if let Some(&r) = self.shared.get(name) {
            return r;
        }
        let r = self.g.add_rule(name);
        self.shared.insert(name, r);
        build(&mut self.g, r);
        r
    }

    /// JSON string: `"` chars `"` with escapes. Multibyte characters are
    /// modeled as *valid UTF-8 sequences* (lead byte + the right number of
    /// continuation bytes, surrogate range excluded), so byte-level token
    /// masking can never strand a partial character in the output —
    /// the same treatment XGrammar applies.
    fn string_rule(&mut self) -> usize {
        self.shared_rule("json-string", |g, r| {
            let cls = |ranges: Vec<(u8, u8)>| Sym::Class(ByteClass { ranges, negated: false });
            let cont = || cls(vec![(0x80, 0xBF)]);
            // ASCII printable minus quote/backslash.
            let ascii = cls(vec![(0x20, 0x21), (0x23, 0x5B), (0x5D, 0x7F)]);
            let utf8 = g.add_rule("json-utf8-char");
            g.add_alt(utf8, vec![ascii]);
            g.add_alt(utf8, vec![cls(vec![(0xC2, 0xDF)]), cont()]);
            g.add_alt(utf8, vec![cls(vec![(0xE0, 0xE0)]), cls(vec![(0xA0, 0xBF)]), cont()]);
            g.add_alt(utf8, vec![cls(vec![(0xE1, 0xEC), (0xEE, 0xEF)]), cont(), cont()]);
            g.add_alt(utf8, vec![cls(vec![(0xED, 0xED)]), cls(vec![(0x80, 0x9F)]), cont()]);
            g.add_alt(utf8, vec![cls(vec![(0xF0, 0xF0)]), cls(vec![(0x90, 0xBF)]), cont(), cont()]);
            g.add_alt(utf8, vec![cls(vec![(0xF1, 0xF3)]), cont(), cont(), cont()]);
            g.add_alt(utf8, vec![cls(vec![(0xF4, 0xF4)]), cls(vec![(0x80, 0x8F)]), cont(), cont()]);
            let plain = Sym::Ref(utf8);
            let esc_simple = Sym::Class(ByteClass {
                ranges: [b'"', b'\\', b'/', b'b', b'f', b'n', b'r', b't']
                    .iter()
                    .map(|&c| (c, c))
                    .collect(),
                negated: false,
            });
            let hex = || {
                Sym::Class(ByteClass {
                    ranges: vec![(b'0', b'9'), (b'a', b'f'), (b'A', b'F')],
                    negated: false,
                })
            };
            let chars = g.add_rule("json-string-chars");
            // chars := ε | plain chars | '\' esc chars
            g.add_alt(chars, Vec::new());
            g.add_alt(chars, vec![plain, Sym::Ref(chars)]);
            let mut esc = vec![Sym::Class(ByteClass::byte(b'\\'))];
            let esc_alt = g.add_rule("json-escape");
            g.add_alt(esc_alt, vec![esc_simple]);
            g.add_alt(
                esc_alt,
                vec![Sym::Class(ByteClass::byte(b'u')), hex(), hex(), hex(), hex()],
            );
            esc.push(Sym::Ref(esc_alt));
            esc.push(Sym::Ref(chars));
            g.add_alt(chars, esc);

            let mut alt = Grammar::lit(b"\"");
            alt.push(Sym::Ref(chars));
            alt.extend(Grammar::lit(b"\""));
            g.add_alt(r, alt);
        })
    }

    /// JSON number.
    fn number_rule(&mut self) -> usize {
        let int = self.integer_rule();
        self.shared_rule("json-number", |g, r| {
            let digit = || Sym::Class(ByteClass { ranges: vec![(b'0', b'9')], negated: false });
            // frac := "." [0-9]+ ; exp := [eE] [+-]? [0-9]+
            let digits1 = {
                let d = g.add_rule("digits");
                g.add_alt(d, vec![digit()]);
                g.add_alt(d, vec![digit(), Sym::Ref(d)]);
                d
            };
            let frac = g.add_rule("frac?");
            g.add_alt(frac, Vec::new());
            g.add_alt(frac, {
                let mut v = Grammar::lit(b".");
                v.push(Sym::Ref(digits1));
                v
            });
            let exp = g.add_rule("exp?");
            g.add_alt(exp, Vec::new());
            {
                let e = Sym::Class(ByteClass { ranges: vec![(b'e', b'e'), (b'E', b'E')], negated: false });
                let sign = g.add_rule("sign?");
                g.add_alt(sign, Vec::new());
                g.add_alt(
                    sign,
                    vec![Sym::Class(ByteClass { ranges: vec![(b'+', b'+'), (b'-', b'-')], negated: false })],
                );
                g.add_alt(exp, vec![e, Sym::Ref(sign), Sym::Ref(digits1)]);
            }
            g.add_alt(r, vec![Sym::Ref(int), Sym::Ref(frac), Sym::Ref(exp)]);
        })
    }

    /// JSON integer: -? (0 | [1-9][0-9]*)
    fn integer_rule(&mut self) -> usize {
        self.shared_rule("json-integer", |g, r| {
            let neg = g.add_rule("neg?");
            g.add_alt(neg, Vec::new());
            g.add_alt(neg, Grammar::lit(b"-"));
            let nz = Sym::Class(ByteClass { ranges: vec![(b'1', b'9')], negated: false });
            let d0 = g.add_rule("digits*");
            g.add_alt(d0, Vec::new());
            g.add_alt(
                d0,
                vec![
                    Sym::Class(ByteClass { ranges: vec![(b'0', b'9')], negated: false }),
                    Sym::Ref(d0),
                ],
            );
            g.add_alt(r, vec![Sym::Ref(neg), Sym::Class(ByteClass::byte(b'0'))]);
            g.add_alt(r, vec![Sym::Ref(neg), nz, Sym::Ref(d0)]);
        })
    }

    /// Any JSON value (compact form).
    fn any_value(&mut self) -> usize {
        if let Some(&r) = self.shared.get("json-value") {
            return r;
        }
        let r = self.g.add_rule("json-value");
        self.shared.insert("json-value", r);
        let string = self.string_rule();
        let number = self.number_rule();
        let object = self.any_object_inner(r);
        let array = self.any_array_inner(r);
        self.g.add_alt(r, vec![Sym::Ref(string)]);
        self.g.add_alt(r, vec![Sym::Ref(number)]);
        self.g.add_alt(r, vec![Sym::Ref(object)]);
        self.g.add_alt(r, vec![Sym::Ref(array)]);
        self.g.add_alt(r, Grammar::lit(b"true"));
        self.g.add_alt(r, Grammar::lit(b"false"));
        self.g.add_alt(r, Grammar::lit(b"null"));
        r
    }

    fn any_object(&mut self) -> usize {
        let value = self.any_value();
        self.any_object_inner(value)
    }

    fn any_object_inner(&mut self, value: usize) -> usize {
        if let Some(&r) = self.shared.get("json-object") {
            return r;
        }
        let r = self.g.add_rule("json-object");
        self.shared.insert("json-object", r);
        let string = self.string_rule();
        // member := string ":" value ; obj := "{" (member ("," member)*)? "}"
        let member = self.g.add_rule("json-member");
        let mut m = vec![Sym::Ref(string)];
        m.extend(Grammar::lit(b":"));
        m.push(Sym::Ref(value));
        self.g.add_alt(member, m);
        let mut rep = Grammar::lit(b",");
        rep.push(Sym::Ref(member));
        let more = self.g.star(rep, "json-object");
        let inner = self.g.opt(vec![Sym::Ref(member), more], "json-object");
        let mut alt = Grammar::lit(b"{");
        alt.push(inner);
        alt.extend(Grammar::lit(b"}"));
        self.g.add_alt(r, alt);
        r
    }

    fn any_array_inner(&mut self, value: usize) -> usize {
        if let Some(&r) = self.shared.get("json-array") {
            return r;
        }
        let r = self.g.add_rule("json-array");
        self.shared.insert("json-array", r);
        let mut rep = Grammar::lit(b",");
        rep.push(Sym::Ref(value));
        let more = self.g.star(rep, "json-array");
        let inner = self.g.opt(vec![Sym::Ref(value), more], "json-array");
        let mut alt = Grammar::lit(b"[");
        alt.push(inner);
        alt.extend(Grammar::lit(b"]"));
        self.g.add_alt(r, alt);
        r
    }
}
