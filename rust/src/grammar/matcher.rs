//! Pushdown matcher + vocabulary masking.
//!
//! The automaton state is a *set* of stacks (the grammar is
//! nondeterministic); each stack is a list of (rule, alt, dot) frames.
//! `advance(byte)` steps every stack; a stack survives if some path
//! consumes the byte. The state is "accepting" when some stack has fully
//! unwound (the root derivation is complete).
//!
//! Token masking walks the tokenizer vocabulary and simulates each
//! token's bytes (llama.cpp-style), with three XGrammar-inspired
//! accelerations:
//!   * a per-grammar ahead-of-time vocabulary partition
//!     ([`super::CompiledGrammar`]): context-independent tokens are
//!     resolved at compile time, so the runtime walk only touches the
//!     context-dependent residue;
//!   * an adaptive mask cache keyed by the state fingerprint — decode
//!     revisits the same automaton states constantly (e.g. "inside a JSON
//!     string"), so residue masks are computed once per distinct state
//!     and evicted LRU-style under a capacity bound;
//!   * a per-state first-byte filter: tokens whose first byte can't be
//!     consumed are rejected without simulating the rest.

use super::bitmask::TokenBitmask;
use super::compiler::CompiledGrammar;
use super::grammar::{Grammar, Sym};
use crate::lru::LruMap;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::rc::Rc;

/// One stack frame: position `dot` within alternative `alt` of `rule`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Frame {
    rule: u32,
    alt: u32,
    dot: u32,
}

type Stack = Vec<Frame>;

/// Matcher over a compiled grammar.
///
/// Cloning is cheap-ish (the grammar is shared behind an `Rc`; only the
/// live stack-set is copied) and is how the AOT compiler enumerates
/// reachable automaton states.
#[derive(Clone)]
pub struct GrammarMatcher {
    grammar: Rc<Grammar>,
    stacks: Vec<Stack>,
    /// Bytes accepted so far (for error reporting / rewind in tests).
    consumed: usize,
}

impl GrammarMatcher {
    /// Start a matcher at the grammar's root, epsilon-closed.
    pub fn new(grammar: Rc<Grammar>) -> Self {
        let mut m = Self { grammar, stacks: Vec::new(), consumed: 0 };
        // Seed: one stack per root alternative, then epsilon-close.
        let root_alts = m.grammar.rules[0].alts.len();
        for alt in 0..root_alts {
            m.push_closed(vec![Frame { rule: 0, alt: alt as u32, dot: 0 }]);
        }
        m.dedup();
        m
    }

    /// Number of bytes accepted so far.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// True if the input so far is a complete derivation of the grammar.
    pub fn is_accepting(&self) -> bool {
        self.stacks.iter().any(|s| s.is_empty())
    }

    /// True if no continuation exists (dead state; only possible after
    /// feeding bytes the grammar rejects — the engine never does).
    pub fn is_dead(&self) -> bool {
        self.stacks.is_empty()
    }

    /// Fingerprint of the automaton state (mask-cache key).
    pub fn fingerprint(&self) -> u64 {
        let mut keys: Vec<u64> = self
            .stacks
            .iter()
            .map(|s| {
                let mut h = DefaultHasher::new();
                s.hash(&mut h);
                h.finish()
            })
            .collect();
        keys.sort_unstable();
        let mut h = DefaultHasher::new();
        keys.hash(&mut h);
        h.finish()
    }

    /// Feed one byte. Returns false (and leaves the state dead) if no
    /// stack can consume it.
    pub fn advance(&mut self, b: u8) -> bool {
        let old = std::mem::take(&mut self.stacks);
        for stack in &old {
            self.step_byte(stack, b);
        }
        self.dedup();
        if self.stacks.is_empty() {
            false
        } else {
            self.consumed += 1;
            true
        }
    }

    /// Feed a byte string; false if rejected at any point (state is then
    /// dead — callers should treat the request as failed).
    pub fn advance_bytes(&mut self, bytes: &[u8]) -> bool {
        bytes.iter().all(|&b| self.advance(b))
    }

    /// Would `bytes` be accepted from the current state? (No mutation.)
    pub fn test_bytes(&self, bytes: &[u8]) -> bool {
        let mut stacks: Vec<Stack> = self.stacks.clone();
        for &b in bytes {
            let mut next = TempState { grammar: &self.grammar, stacks: Vec::new() };
            for stack in &stacks {
                next.step_byte(stack, b);
            }
            stacks = next.stacks;
            if stacks.is_empty() {
                return false;
            }
        }
        true
    }

    /// Accept a sampled token's bytes (engine hot path).
    pub fn accept_token(&mut self, token_bytes: &[u8]) -> bool {
        self.advance_bytes(token_bytes)
    }

    /// Compute the allowed-token mask for the whole vocabulary as a packed
    /// [`TokenBitmask`]. `token_bytes(i)` supplies each token's byte
    /// string; empty strings (specials/unused) are banned except
    /// `eos_allowed` handling done by the caller via `is_accepting`.
    pub fn token_mask<'a>(
        &self,
        vocab_size: usize,
        token_bytes: impl Fn(u32) -> &'a [u8],
    ) -> TokenBitmask {
        // First-byte filter: which bytes are consumable right now?
        let first = self.first_byte_set();
        let mut mask = TokenBitmask::new(vocab_size);
        for i in 0..vocab_size {
            let bytes = token_bytes(i as u32);
            if bytes.is_empty() {
                continue;
            }
            if !first[bytes[0] as usize] {
                continue;
            }
            if bytes.len() == 1 || self.test_bytes(bytes) {
                mask.allow(i);
            }
        }
        mask
    }

    /// The exact set of bytes consumable from the current state. Stack
    /// tops are epsilon-closed (each sits on a byte class), so `advance`
    /// succeeds for a byte iff its entry here is `true`. The compile-time
    /// state enumeration uses this to drive its byte-level BFS.
    pub(crate) fn first_byte_set(&self) -> [bool; 256] {
        let mut first = [false; 256];
        for stack in &self.stacks {
            self.collect_first_bytes(stack, &mut first);
        }
        first
    }

    // -- internals ----------------------------------------------------------

    /// Epsilon-close `stack` (expand Refs / pop completed frames) and add
    /// every resulting configuration.
    fn push_closed(&mut self, stack: Stack) {
        let grammar = self.grammar.clone();
        close_into(&grammar, stack, &mut self.stacks);
    }

    fn step_byte(&mut self, stack: &Stack, b: u8) {
        let grammar = self.grammar.clone();
        step_byte_into(&grammar, stack, b, &mut self.stacks);
    }

    fn collect_first_bytes(&self, stack: &Stack, first: &mut [bool; 256]) {
        // Top frame is epsilon-closed already: its dot sits on a Class or
        // the stack is empty (accepting; no byte consumable).
        if let Some(top) = stack.last() {
            let alt = &self.grammar.rules[top.rule as usize].alts[top.alt as usize];
            if let Some(Sym::Class(c)) = alt.get(top.dot as usize) {
                for byte in 0..=255u8 {
                    if !first[byte as usize] && c.matches(byte) {
                        first[byte as usize] = true;
                    }
                }
            }
        }
    }

    fn dedup(&mut self) {
        dedup_stacks(&mut self.stacks);
    }
}

fn dedup_stacks(stacks: &mut Vec<Stack>) {
    if stacks.len() <= 1 {
        return;
    }
    let mut seen = std::collections::HashSet::new();
    stacks.retain(|s| {
        let mut h = DefaultHasher::new();
        s.hash(&mut h);
        seen.insert(h.finish())
    });
    // Nondeterminism bound: pathological grammars could explode; keep
    // the engine deterministic by capping (documented limitation).
    const MAX_STACKS: usize = 512;
    if stacks.len() > MAX_STACKS {
        stacks.truncate(MAX_STACKS);
    }
}

/// Stateless helper so `test_bytes` can reuse the same stepping code
/// without borrowing issues.
struct TempState<'g> {
    grammar: &'g Grammar,
    stacks: Vec<Stack>,
}

impl<'g> TempState<'g> {
    fn step_byte(&mut self, stack: &Stack, b: u8) {
        step_byte_into(self.grammar, stack, b, &mut self.stacks);
    }
}

/// Epsilon closure: expand until every stack's top dot is at a Class (or
/// the stack is empty). Pushes results into `out`.
fn close_into(grammar: &Grammar, stack: Stack, out: &mut Vec<Stack>) {
    // Depth-first with an explicit worklist; a visited set guards against
    // cyclic epsilon derivations (e.g. R -> R | ...).
    let mut work = vec![stack];
    let mut visited: std::collections::HashSet<u64> = std::collections::HashSet::new();
    while let Some(mut s) = work.pop() {
        // Pop completed frames.
        loop {
            match s.last() {
                None => break,
                Some(top) => {
                    let alt = &grammar.rules[top.rule as usize].alts[top.alt as usize];
                    if top.dot as usize >= alt.len() {
                        s.pop();
                        // advance the parent frame past the Ref
                        if let Some(parent) = s.last_mut() {
                            parent.dot += 1;
                        }
                    } else {
                        break;
                    }
                }
            }
        }
        let mut h = DefaultHasher::new();
        s.hash(&mut h);
        if !visited.insert(h.finish()) {
            continue;
        }
        match s.last() {
            None => out.push(s), // accepting configuration
            Some(top) => {
                let alt = &grammar.rules[top.rule as usize].alts[top.alt as usize];
                match &alt[top.dot as usize] {
                    Sym::Class(_) => out.push(s),
                    Sym::Ref(r) => {
                        // Tail-call elimination: if the Ref is the frame's
                        // last symbol, the parent frame has no further work
                        // once the child completes — replace it instead of
                        // stacking. Keeps right-recursive rules (the `*`/`+`
                        // desugaring) at constant stack depth, which also
                        // makes automaton states recur => mask-cache hits.
                        let is_tail = top.dot as usize == alt.len() - 1;
                        let n_alts = grammar.rules[*r].alts.len();
                        for a in 0..n_alts {
                            let mut child = s.clone();
                            if is_tail {
                                child.pop();
                            }
                            child.push(Frame { rule: *r as u32, alt: a as u32, dot: 0 });
                            work.push(child);
                        }
                    }
                }
            }
        }
    }
}

/// Consume `b` at the top of `stack` (which must be closed: top dot on a
/// Class) and epsilon-close the successor into `out`.
fn step_byte_into(grammar: &Grammar, stack: &Stack, b: u8, out: &mut Vec<Stack>) {
    let Some(top) = stack.last() else { return };
    let alt = &grammar.rules[top.rule as usize].alts[top.alt as usize];
    if let Some(Sym::Class(c)) = alt.get(top.dot as usize) {
        if c.matches(b) {
            let mut next = stack.clone();
            next.last_mut().unwrap().dot += 1;
            close_into(grammar, next, out);
        }
    }
}

/// Byte-trie over the tokenizer vocabulary. Token-mask computation walks
/// the trie once per automaton state (shared token prefixes are stepped
/// once), instead of simulating every token independently.
pub struct VocabTrie {
    /// Arena of nodes; node 0 is the root.
    children: Vec<Vec<(u8, u32)>>,
    /// Token ids that end at each node.
    terminal: Vec<Vec<u32>>,
    vocab_size: usize,
}

impl VocabTrie {
    pub fn build<'a>(vocab_size: usize, token_bytes: impl Fn(u32) -> &'a [u8]) -> Self {
        let mut t = Self {
            children: vec![Vec::new()],
            terminal: vec![Vec::new()],
            vocab_size,
        };
        for id in 0..vocab_size as u32 {
            let bytes = token_bytes(id);
            if bytes.is_empty() {
                continue; // specials/unused: never grammar-eligible
            }
            let mut node = 0usize;
            for &b in bytes {
                node = match t.children[node].iter().find(|(c, _)| *c == b) {
                    Some(&(_, n)) => n as usize,
                    None => {
                        let n = t.children.len();
                        t.children.push(Vec::new());
                        t.terminal.push(Vec::new());
                        t.children[node].push((b, n as u32));
                        n
                    }
                };
            }
            t.terminal[node].push(id);
        }
        t
    }

    /// Number of token ids the trie was built over (including skipped
    /// empty-byte tokens; masks produced from this trie use this length).
    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    /// Number of arena nodes (distinct byte prefixes, plus the root).
    pub fn node_count(&self) -> usize {
        self.children.len()
    }

    /// Shared arena DFS over the trie, generic over the per-branch
    /// simulation state `S`.
    ///
    /// Every live state is kept in one shared arena `Vec` — a child
    /// node's states are appended on descent and truncated away on
    /// backtrack — instead of cloning a fresh `Vec<S>` per trie node, so
    /// the walk's only steady-state allocations are whatever `step`
    /// itself produces. `step` receives the parent's states and one edge
    /// byte and pushes the surviving successor states; when it pushes
    /// nothing the whole subtree is dead and is skipped. `grant` receives
    /// the token ids ending at each node reached alive.
    ///
    /// Two callers share this walk (the XGrammar compile/runtime split):
    /// the runtime residue walk ([`GrammarMatcher::token_mask_trie`],
    /// `S` = stack set) and the compiler's ahead-of-time vocabulary
    /// sweep (`S` = position bitset).
    pub fn walk<S>(
        &self,
        init: Vec<S>,
        mut step: impl FnMut(&[S], u8, &mut Vec<S>),
        mut grant: impl FnMut(&[u32]),
    ) {
        let mut arena: Vec<S> = init;
        let mut scratch: Vec<S> = Vec::new();
        let mut dfs = vec![DfsFrame { node: 0, start: 0, end: arena.len(), child: 0 }];
        while let Some(top) = dfs.last_mut() {
            let node = top.node as usize;
            if top.child >= self.children[node].len() {
                // Backtrack: drop this node's states (and nothing else —
                // descendants were truncated when they popped).
                let start = top.start;
                dfs.pop();
                arena.truncate(start);
                continue;
            }
            let (byte, child) = self.children[node][top.child];
            top.child += 1;
            let (s, e) = (top.start, top.end);

            scratch.clear();
            step(&arena[s..e], byte, &mut scratch);
            if scratch.is_empty() {
                continue; // whole subtree dead
            }
            grant(&self.terminal[child as usize]);
            if !self.children[child as usize].is_empty() {
                let start = arena.len();
                arena.append(&mut scratch);
                dfs.push(DfsFrame { node: child, start, end: arena.len(), child: 0 });
            }
        }
    }
}

/// One in-flight node of the trie DFS: `arena[start..end]` holds the
/// simulation states after consuming the byte path to `node`; `child` is
/// the next outgoing edge to try.
struct DfsFrame {
    node: u32,
    start: usize,
    end: usize,
    child: usize,
}

impl GrammarMatcher {
    /// Trie-accelerated mask: one DFS over the vocabulary trie, stepping
    /// the stack-set per *distinct byte prefix* instead of per token (the
    /// arena mechanics live in [`VocabTrie::walk`]).
    ///
    /// Pass the full vocabulary trie for a from-scratch mask, or a
    /// [`super::CompiledGrammar`]'s residue trie to walk only the
    /// context-dependent tokens (the mask is zero outside the trie's
    /// tokens either way).
    pub fn token_mask_trie(&self, trie: &VocabTrie) -> TokenBitmask {
        let mut mask = TokenBitmask::new(trie.vocab_size);
        let grammar = self.grammar.clone();
        trie.walk(
            self.stacks.clone(),
            |stacks, byte, out| {
                for stack in stacks {
                    step_byte_into(&grammar, stack, byte, out);
                }
                dedup_stacks(out);
            },
            |tokens| {
                for &tok in tokens {
                    mask.allow(tok as usize);
                }
            },
        );
        mask
    }
}

/// Counter snapshot of a [`MaskCache`] (surfaced through the engine's
/// `stats_json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MaskCacheCounters {
    /// Lookups answered by a cached mask (an `Rc` pointer clone).
    pub hits: u64,
    /// Lookups that paid a residue trie walk.
    pub misses: u64,
    /// Entries evicted by the LRU capacity bound.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Capacity bound.
    pub capacity: usize,
}

/// Adaptive token-mask cache: state fingerprint -> packed mask, LRU-bounded.
///
/// Two layers of the XGrammar adaptive-mask scheme meet here:
///   * **compile time** — the [`CompiledGrammar`] already classified the
///     context-independent vocabulary, so a miss only walks the residue
///     trie and ORs the precomputed base-accept mask;
///   * **runtime** — decode revisits the same automaton states
///     constantly, so each distinct state pays that residue walk once;
///     subsequent visits are a hash lookup returning an
///     `Rc<TokenBitmask>` clone: O(1), never an O(vocab) copy.
///
/// Eviction is a capacity-bounded LRU keyed by the state fingerprint
/// (the shared [`LruMap`] clock-stamp policy: when a miss would exceed
/// `capacity`, the single least-recently-used entry is dropped,
/// deterministically). Hot states (e.g. "inside a JSON string")
/// therefore survive grammars whose state count exceeds the capacity,
/// where the previous full-flush policy threw the whole working set
/// away.
///
/// [`MaskCache::seeded`] additionally pre-populates the cache with the
/// per-state masks an exact compile pass already computed, so decoding
/// an exactly-compiled grammar never pays a residue walk at all.
pub struct MaskCache {
    compiled: Rc<CompiledGrammar>,
    entries: LruMap<u64, Rc<TokenBitmask>>,
    hits: u64,
    misses: u64,
}

impl MaskCache {
    /// A cache over `compiled`'s residue masks holding at most `capacity`
    /// distinct automaton states (at least one). Starts empty; every
    /// first visit to a state is a miss.
    pub fn new(compiled: Rc<CompiledGrammar>, capacity: usize) -> Self {
        Self {
            compiled,
            entries: LruMap::new(capacity),
            hits: 0,
            misses: 0,
        }
    }

    /// Engine-facing constructor: adapts `capacity` down to the exact
    /// state count when the compile pass enumerated every state (a
    /// larger cache could never fill), then seeds the cache with the
    /// masks that pass already computed. Seeded entries count as
    /// neither hits nor misses; lookups that land on them are hits.
    pub fn seeded(compiled: Rc<CompiledGrammar>, capacity: usize) -> Self {
        let capacity = if compiled.is_exact() {
            capacity.min(compiled.states_explored().max(1))
        } else {
            capacity
        };
        let mut cache = Self::new(compiled, capacity);
        let n = cache.entries.capacity();
        let compiled = cache.compiled.clone();
        for (fp, mask) in compiled.state_masks().iter().take(n) {
            cache.entries.insert(*fp, Rc::new(mask.clone()));
        }
        cache
    }

    /// The compiled grammar this cache computes masks for.
    pub fn compiled(&self) -> &Rc<CompiledGrammar> {
        &self.compiled
    }

    /// The mask for `matcher`'s current state: a pointer clone on a hit,
    /// `base_accept | residue-walk` on a miss (cached afterwards, evicting
    /// the least-recently-used state if at capacity).
    pub fn get_or_compute(&mut self, matcher: &GrammarMatcher) -> Rc<TokenBitmask> {
        let key = matcher.fingerprint();
        if let Some(mask) = self.entries.get(&key) {
            self.hits += 1;
            return mask.clone();
        }
        self.misses += 1;
        let mask = Rc::new(self.compiled.mask_for(matcher));
        self.entries.insert(key, mask.clone());
        mask
    }

    /// `(hits, misses)` — kept for existing callers; see
    /// [`MaskCache::counters`] for the full set.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Snapshot of all counters.
    pub fn counters(&self) -> MaskCacheCounters {
        MaskCacheCounters {
            hits: self.hits,
            misses: self.misses,
            evictions: self.entries.evictions(),
            entries: self.entries.len(),
            capacity: self.entries.capacity(),
        }
    }
}
