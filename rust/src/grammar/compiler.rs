//! Ahead-of-time grammar × vocabulary analysis — the XGrammar
//! *compile-time* half of the adaptive token-mask scheme (WebLLM §2.4).
//!
//! XGrammar's key observation is that for most grammars the bulk of the
//! vocabulary can be classified once, ahead of time, independent of the
//! matcher state: a token either can never appear (its bytes are not a
//! path through the grammar's byte structure from *any* state) or is
//! acceptable from *every* state. Only the context-*dependent* residue
//! needs per-state runtime work. This module runs that classification
//! once per compiled grammar and emits a [`CompiledGrammar`]:
//!
//!   * `base_accept` — tokens acceptable from every reachable automaton
//!     state (exact, via bounded reachable-state enumeration);
//!   * `base_reject` — tokens acceptable from no reachable state (exact
//!     when enumeration completes, else via a sound position-NFA
//!     over-approximation that works for unboundedly recursive grammars);
//!   * `residue` — everything else, materialized as a pruned
//!     [`VocabTrie`] so the runtime walk steps only residue prefixes.
//!
//! A [`super::MaskCache`] miss then costs `base_accept | residue-walk`
//! instead of a whole-vocabulary walk; the compile-time sweep and the
//! runtime walk share the same arena DFS ([`VocabTrie::walk`]).
//!
//! Soundness invariants (pinned token-for-token by the equivalence
//! property test in `grammar::tests`): for every reachable state `S`,
//! `base_accept ⊆ mask(S)` and `base_reject ∩ mask(S) = ∅`, hence
//! `mask(S) == base_accept ∪ residue_walk(S)` exactly.

use super::bitmask::TokenBitmask;
use super::grammar::{ByteClass, Grammar, Sym};
use super::matcher::{GrammarMatcher, VocabTrie};
use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::time::Instant;

/// Bound on the exact reachable-state enumeration. Grammars whose byte
/// automaton stays under this (finite-state in practice: no unbounded
/// recursion) get *exact* base sets; the rest fall back to the sound
/// position-NFA approximation with an empty `base_accept`.
const MAX_EXACT_STATES: usize = 512;

/// Work budget for the exact path's per-state mask sweep, as
/// `states × vocab`. Compilation happens at admission (synchronously, on
/// the engine thread); past this budget the per-state walks could stall
/// a first request for seconds on a 100k+ vocabulary, so such grammars
/// take the NFA partition instead.
const MAX_EXACT_MASK_WORK: usize = 32 << 20;

/// Result of [`reachable_states`]: the enumerated automaton states and
/// whether the enumeration closed (visited everything) under the cap.
pub(crate) struct ReachableStates {
    pub states: Vec<GrammarMatcher>,
    pub complete: bool,
}

/// Byte-level BFS over the automaton's state graph from the start state,
/// deduplicated by state fingerprint, stopping (with `complete = false`)
/// once more than `cap` states have been discovered.
pub(crate) fn reachable_states(grammar: &Rc<Grammar>, cap: usize) -> ReachableStates {
    let init = GrammarMatcher::new(grammar.clone());
    let mut seen: HashSet<u64> = HashSet::new();
    seen.insert(init.fingerprint());
    let mut states = vec![init];
    let mut complete = true;
    let mut i = 0;
    'bfs: while i < states.len() {
        let first = states[i].first_byte_set();
        for b in 0..=255u8 {
            if !first[b as usize] {
                continue;
            }
            let mut next = states[i].clone();
            if !next.advance(b) {
                continue; // unreachable: first_byte_set is exact
            }
            if seen.insert(next.fingerprint()) {
                if states.len() >= cap {
                    complete = false;
                    break 'bfs;
                }
                states.push(next);
            }
        }
        i += 1;
    }
    ReachableStates { states, complete }
}

/// A grammar compiled against a concrete vocabulary: the
/// context-independent token partition plus the residue trie the runtime
/// walks per state. Share one per grammar via `Rc` (the engine keys them
/// by grammar identity so every sequence of every request reuses the
/// same compilation).
pub struct CompiledGrammar {
    grammar: Rc<Grammar>,
    vocab_size: usize,
    base_accept: TokenBitmask,
    base_reject: TokenBitmask,
    residue: Vec<u32>,
    /// Trie over the residue tokens only (full-vocab token ids, so masks
    /// from it align with whole-vocabulary masks).
    residue_trie: VocabTrie,
    exact: bool,
    states_explored: usize,
    compile_seconds: f64,
    /// `(fingerprint, full mask)` for every enumerated state — the
    /// per-state masks the exact sweep computes anyway, kept to seed
    /// [`super::MaskCache`]s instead of being discarded. Empty when the
    /// compilation fell back to the NFA approximation.
    state_masks: Vec<(u64, TokenBitmask)>,
    /// Fingerprint → forced token for every *forced* state (non-accepting
    /// with a singleton mask). `Some` only when the enumeration was
    /// exact, in which case absence from the map proves "not forced".
    forced: Option<HashMap<u64, u32>>,
}

impl CompiledGrammar {
    /// Run the one-shot vocabulary partition for `grammar` over the
    /// vocabulary described by `trie` + `token_bytes` (the same pair the
    /// engine builds at load; `token_bytes` must agree with the trie).
    ///
    /// ```
    /// use std::rc::Rc;
    /// use webllm::grammar::{parse_ebnf, CompiledGrammar, MaskCache, VocabTrie};
    ///
    /// let grammar = Rc::new(parse_ebnf(r#"root ::= ("ab" | "cd")+"#).unwrap());
    /// let vocab: Vec<&[u8]> = vec![b"a", b"ab", b"cd", b"zz", b"\n"];
    /// let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize]);
    /// let compiled = Rc::new(CompiledGrammar::compile(
    ///     grammar, &trie, |i| vocab[i as usize],
    /// ));
    /// // "zz" and "\n" can never appear: context-independent rejects.
    /// assert!(compiled.base_reject().is_allowed(3));
    /// assert!(compiled.base_reject().is_allowed(4));
    ///
    /// let mut cache = MaskCache::new(compiled.clone(), 64);
    /// let mask = cache.get_or_compute(&compiled.matcher());
    /// assert!(mask.is_allowed(1) && !mask.is_allowed(3));
    /// ```
    pub fn compile<'a>(
        grammar: Rc<Grammar>,
        trie: &VocabTrie,
        token_bytes: impl Fn(u32) -> &'a [u8],
    ) -> CompiledGrammar {
        let t0 = Instant::now();
        let vocab_size = trie.vocab_size();
        let reached = reachable_states(&grammar, MAX_EXACT_STATES);
        let exact = reached.complete
            && reached.states.len().saturating_mul(vocab_size) <= MAX_EXACT_MASK_WORK;
        let mut state_masks = Vec::new();
        let mut forced = None;
        let (base_accept, base_reject) = if exact {
            // Exact: intersect/union the true mask of every reachable
            // state. Tokens in no mask can never appear; tokens in every
            // mask are state-independent. The per-state masks are kept
            // (they seed the runtime mask cache), and non-accepting
            // singleton-mask states are indexed as *forced*: their next
            // token is determined, so the engine can append it without a
            // model or sampler call.
            let mut accept = TokenBitmask::all_allowed(vocab_size);
            let mut ever = TokenBitmask::new(vocab_size);
            let mut forced_map = HashMap::new();
            for state in &reached.states {
                let mask = state.token_mask_trie(trie);
                accept.and_with(&mask);
                ever.or_with(&mask);
                if !state.is_accepting() && mask.count_allowed() == 1 {
                    let tok = mask.iter_allowed().next().unwrap() as u32;
                    forced_map.insert(state.fingerprint(), tok);
                }
                state_masks.push((state.fingerprint(), mask));
            }
            forced = Some(forced_map);
            (accept, ever.complement())
        } else {
            // Either recursion made the state space unbounded, or the
            // per-state sweep would blow the admission-time budget:
            // approximate with the position NFA (sound: it
            // over-approximates what any state could consume, so its
            // complement is always-rejected), and give up on base_accept
            // (∅ is trivially sound).
            let nfa = PositionNfa::build(&grammar);
            (TokenBitmask::new(vocab_size), nfa.sweep(trie).complement())
        };

        let mut residue_set = base_accept.clone();
        residue_set.or_with(&base_reject);
        let residue_set = residue_set.complement();
        let residue: Vec<u32> = residue_set.iter_allowed().map(|i| i as u32).collect();
        let residue_trie = VocabTrie::build(vocab_size, |i| {
            if residue_set.is_allowed(i as usize) {
                token_bytes(i)
            } else {
                &[]
            }
        });

        CompiledGrammar {
            grammar,
            vocab_size,
            base_accept,
            base_reject,
            residue,
            residue_trie,
            exact,
            states_explored: reached.states.len(),
            compile_seconds: t0.elapsed().as_secs_f64(),
            state_masks,
            forced,
        }
    }

    /// The grammar this compilation is for.
    pub fn grammar(&self) -> &Rc<Grammar> {
        &self.grammar
    }

    /// A fresh matcher at this grammar's start state.
    pub fn matcher(&self) -> GrammarMatcher {
        GrammarMatcher::new(self.grammar.clone())
    }

    /// Number of token ids the compilation covers.
    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    /// Tokens acceptable from **every** reachable state (empty when the
    /// state enumeration hit its bound).
    pub fn base_accept(&self) -> &TokenBitmask {
        &self.base_accept
    }

    /// Tokens acceptable from **no** reachable state (includes
    /// empty-byte specials, which are never grammar-eligible).
    pub fn base_reject(&self) -> &TokenBitmask {
        &self.base_reject
    }

    /// The context-dependent token ids (ascending): everything in
    /// neither base set; the only tokens the per-state runtime walk
    /// touches.
    pub fn residue(&self) -> &[u32] {
        &self.residue
    }

    /// The pruned trie over [`CompiledGrammar::residue`].
    pub fn residue_trie(&self) -> &VocabTrie {
        &self.residue_trie
    }

    /// Whether the base sets are exact (state enumeration completed
    /// within the state and mask-work budgets) rather than the sound NFA
    /// approximation.
    pub fn is_exact(&self) -> bool {
        self.exact
    }

    /// Automaton states visited by the compile-time enumeration.
    pub fn states_explored(&self) -> usize {
        self.states_explored
    }

    /// Wall-clock cost of [`CompiledGrammar::compile`] (the one-shot cost
    /// the per-state savings amortize; reported by `benches/grammar.rs`).
    pub fn compile_seconds(&self) -> f64 {
        self.compile_seconds
    }

    /// Fraction of the vocabulary classified ahead of time
    /// (`(|base_accept| + |base_reject|) / vocab`).
    pub fn context_independent_fraction(&self) -> f64 {
        if self.vocab_size == 0 {
            return 0.0;
        }
        let ci = self.base_accept.count_allowed() + self.base_reject.count_allowed();
        ci as f64 / self.vocab_size as f64
    }

    /// The full vocabulary mask for `matcher`'s current state:
    /// `base_accept | residue-walk` — equal, token for token, to a
    /// whole-vocabulary [`GrammarMatcher::token_mask_trie`] walk, but
    /// only stepping the context-dependent trie.
    pub fn mask_for(&self, matcher: &GrammarMatcher) -> TokenBitmask {
        let mut mask = matcher.token_mask_trie(&self.residue_trie);
        mask.or_with(&self.base_accept);
        mask
    }

    /// The per-state masks computed by the exact sweep, as
    /// `(fingerprint, mask)` pairs (empty for NFA-approximated
    /// compilations). Used to seed [`super::MaskCache`]s.
    pub fn state_masks(&self) -> &[(u64, TokenBitmask)] {
        &self.state_masks
    }

    /// Compile-time forced-token lookup for `matcher`'s state.
    ///
    /// * `None` — the compilation wasn't exact; forcedness is unknown
    ///   here and the caller must inspect the state's full mask.
    /// * `Some(None)` — proven not forced (accepting, dead, or ≥ 2
    ///   allowed tokens).
    /// * `Some(Some(t))` — the state is non-accepting with exactly one
    ///   allowed token `t`: the sampler can only ever emit `t`.
    pub fn forced_token(&self, matcher: &GrammarMatcher) -> Option<Option<u32>> {
        self.forced
            .as_ref()
            .map(|map| map.get(&matcher.fingerprint()).copied())
    }

    /// Cheap whole-grammar bail for the fast-forward path: `false` means
    /// *no* state of this grammar is ever forced, so per-token forced
    /// lookups can be skipped entirely. (Exact compilations know this
    /// from the forced index; otherwise a `base_accept` with ≥ 2 tokens
    /// proves every mask has ≥ 2 tokens, since it is a subset of all of
    /// them.)
    pub fn ff_possible(&self) -> bool {
        match &self.forced {
            Some(map) => !map.is_empty(),
            None => self.base_accept.count_allowed() <= 1,
        }
    }
}

/// Finite over-approximation of the pushdown automaton, used when exact
/// state enumeration is impossible (unbounded recursion).
///
/// Nodes are the grammar's *positions* — every `(rule, alt, dot)` whose
/// dot sits on a byte class — connected by "consume the class's byte,
/// then epsilon-close" edges where rule *returns* are approximated
/// call-site-insensitively: a completed rule may continue at any
/// occurrence of a reference to it. Any byte string a real state can
/// consume traces a path here (the real return discipline is a subset of
/// the approximated one), so a token whose bytes survive no path from
/// the reachable-position set is rejected in every state.
struct PositionNfa {
    /// 256-bit byte-match table per position.
    byte_match: Vec<[u64; 4]>,
    /// Flattened successor bitsets, `words` u64s per position.
    succ: Vec<u64>,
    /// Whether the position's post-byte closure can complete the root
    /// derivation (the analog of the matcher's empty-stack
    /// configuration): the consumed prefix may be a full derivation even
    /// with no successor positions.
    can_complete: Vec<bool>,
    /// Words per position bitset.
    words: usize,
    /// All reachable positions (the conservative "any stack top" start).
    start: Vec<u64>,
}

impl PositionNfa {
    fn build(g: &Grammar) -> Self {
        let nrules = g.rules.len();
        // Node numbering: one node per (rule, alt, dot) with dot in
        // 0..=len (the "after dot" configurations, contiguous per alt so
        // a byte node's successor configuration is `node + 1`), then a
        // start node and an end node per rule.
        let mut after_base: Vec<Vec<usize>> = Vec::with_capacity(nrules);
        let mut next_id = 0usize;
        for rule in &g.rules {
            let mut bases = Vec::with_capacity(rule.alts.len());
            for alt in &rule.alts {
                bases.push(next_id);
                next_id += alt.len() + 1;
            }
            after_base.push(bases);
        }
        let total_after = next_id;
        let start_node = |r: usize| total_after + r;
        let end_node = |r: usize| total_after + nrules + r;
        let n_nodes = total_after + 2 * nrules;

        let mut eps: Vec<Vec<u32>> = vec![Vec::new(); n_nodes];
        // Byte class at byte nodes (None for pure-epsilon nodes).
        let mut class_at: Vec<Option<usize>> = vec![None; n_nodes];
        let mut classes: Vec<&ByteClass> = Vec::new();
        for (r, rule) in g.rules.iter().enumerate() {
            for (a, alt) in rule.alts.iter().enumerate() {
                let base = after_base[r][a];
                eps[start_node(r)].push(base as u32);
                for (d, sym) in alt.iter().enumerate() {
                    match sym {
                        Sym::Class(c) => {
                            class_at[base + d] = Some(classes.len());
                            classes.push(c);
                        }
                        Sym::Ref(r2) => {
                            eps[base + d].push(start_node(*r2) as u32);
                            // Call-site-insensitive return edge.
                            eps[end_node(*r2)].push((base + d + 1) as u32);
                        }
                    }
                }
                eps[base + alt.len()].push(end_node(r) as u32);
            }
        }

        // Reachability from the root's start node; byte nodes continue
        // into their dot+1 node (consuming their byte).
        let mut reach = vec![false; n_nodes];
        reach[start_node(0)] = true;
        let mut work: Vec<usize> = vec![start_node(0)];
        while let Some(n) = work.pop() {
            if class_at[n].is_some() && !reach[n + 1] {
                reach[n + 1] = true;
                work.push(n + 1);
            }
            for &m in &eps[n] {
                let m = m as usize;
                if !reach[m] {
                    reach[m] = true;
                    work.push(m);
                }
            }
        }

        // Index the reachable byte nodes as positions.
        let mut pos_of_node: Vec<u32> = vec![u32::MAX; n_nodes];
        let mut positions: Vec<usize> = Vec::new();
        for n in 0..n_nodes {
            if reach[n] && class_at[n].is_some() {
                pos_of_node[n] = positions.len() as u32;
                positions.push(n);
            }
        }
        let np = positions.len();
        let words = np.div_ceil(64);

        let mut byte_match = vec![[0u64; 4]; np];
        for (i, &n) in positions.iter().enumerate() {
            let class = classes[class_at[n].unwrap()];
            for b in 0..=255u8 {
                if class.matches(b) {
                    byte_match[i][(b >> 6) as usize] |= 1u64 << (b & 63);
                }
            }
        }

        // Per position: epsilon-closure from `node + 1`, collecting the
        // byte nodes it can stop at and whether it can complete the root.
        let mut succ = vec![0u64; np * words];
        let mut can_complete = vec![false; np];
        let mut seen = vec![false; n_nodes];
        for (i, &n) in positions.iter().enumerate() {
            seen.fill(false);
            seen[n + 1] = true;
            let mut work: Vec<usize> = vec![n + 1];
            while let Some(m) = work.pop() {
                if m == end_node(0) {
                    can_complete[i] = true;
                }
                if class_at[m].is_some() {
                    // A byte node needs its byte before continuing: it is
                    // a successor position, not an epsilon waypoint.
                    let p = pos_of_node[m] as usize;
                    succ[i * words + (p >> 6)] |= 1u64 << (p & 63);
                    continue;
                }
                for &k in &eps[m] {
                    let k = k as usize;
                    if !seen[k] {
                        seen[k] = true;
                        work.push(k);
                    }
                }
            }
        }

        let mut start = vec![0u64; words];
        for i in 0..np {
            start[i >> 6] |= 1u64 << (i & 63);
        }

        PositionNfa { byte_match, succ, can_complete, words, start }
    }

    /// Sweep the vocabulary trie once: a token survives iff the NFA can
    /// consume all its bytes from *some* position path — the complement
    /// is always-rejected. Shares the arena DFS with the runtime walk;
    /// the per-branch state is one position bitset instead of a stack
    /// set.
    fn sweep(&self, trie: &VocabTrie) -> TokenBitmask {
        let mut maybe = TokenBitmask::new(trie.vocab_size());
        let words = self.words;
        trie.walk(
            vec![self.start.clone()],
            |sets: &[Vec<u64>], byte, out: &mut Vec<Vec<u64>>| {
                let mut next = vec![0u64; words];
                let mut completes = false;
                let wi = (byte >> 6) as usize;
                let wb = 1u64 << (byte & 63);
                for set in sets {
                    for (widx, &word) in set.iter().enumerate() {
                        let mut word = word;
                        while word != 0 {
                            let bit = word.trailing_zeros() as usize;
                            word &= word - 1;
                            let p = (widx << 6) + bit;
                            if self.byte_match[p][wi] & wb != 0 {
                                let row = &self.succ[p * words..(p + 1) * words];
                                for (n, &r) in next.iter_mut().zip(row) {
                                    *n |= r;
                                }
                                completes |= self.can_complete[p];
                            }
                        }
                    }
                }
                // Alive if any successor position remains — or the byte
                // can complete the root derivation (the matcher's
                // accepting empty-stack configuration), which still
                // legitimizes tokens ending exactly here.
                if completes || next.iter().any(|&w| w != 0) {
                    out.push(next);
                }
            },
            |tokens| {
                for &tok in tokens {
                    maybe.allow(tok as usize);
                }
            },
        );
        maybe
    }
}
