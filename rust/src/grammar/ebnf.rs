//! GBNF-style EBNF parser (the grammar syntax WebLLM/XGrammar accept for
//! `response_format: {type: "grammar"}`-style requests).
//!
//! Syntax:
//!
//! ```text
//! root  ::= "yes" | "no" ws        # comments run to end of line
//! ws    ::= [ \t\n]*
//! word  ::= [a-zA-Z]+ ("-" [a-z]+)?
//! ```
//!
//! Literals support \n \t \r \\ \" \xHH escapes; classes support ranges,
//! negation ([^...]) and the same escapes. Postfix `* + ?` and bounded
//! repetition `{m} {m,} {m,n}` (counts capped, expansion budgeted) bind to
//! the immediately preceding item; `( ... )` groups; `|` separates
//! alternatives.

use super::grammar::{ByteClass, Grammar, GrammarError, Sym};
use std::collections::HashMap;

/// Parse GBNF-style EBNF text into a byte-level [`Grammar`] (rule 0 is
/// always `root`, which must be defined).
///
/// # Examples
///
/// A grammar whose language is `yes`, `no`, or two digits:
///
/// ```
/// use std::rc::Rc;
/// use webllm::grammar::{parse_ebnf, GrammarMatcher};
///
/// let grammar = parse_ebnf(
///     "root  ::= \"yes\" | \"no\" | digit digit  # comment\n\
///      digit ::= [0-9]",
/// ).unwrap();
/// let g = Rc::new(grammar);
///
/// let mut m = GrammarMatcher::new(g.clone());
/// assert!(m.advance_bytes(b"42") && m.is_accepting());
///
/// let mut m = GrammarMatcher::new(g);
/// assert!(!m.advance_bytes(b"maybe"), "rejected mid-prefix");
/// ```
///
/// Errors are structured ([`GrammarError`]):
///
/// ```
/// use webllm::grammar::{parse_ebnf, GrammarError};
///
/// assert!(matches!(parse_ebnf("foo ::= \"x\""), Err(GrammarError::NoRoot)));
/// assert!(matches!(parse_ebnf("root ::= bar"), Err(GrammarError::UnknownRule(_))));
/// ```
pub fn parse_ebnf(text: &str) -> Result<Grammar, GrammarError> {
    // Pass 1: collect rule names in order (root must become rule 0).
    let mut defs: Vec<(String, &str)> = Vec::new();
    let logical: Vec<String> = LogicalLines::new(text).collect();
    for line in &logical {
        let line = strip_comment(line).trim();
        if line.is_empty() {
            continue;
        }
        let Some((name, body)) = line.split_once("::=") else {
            return Err(GrammarError::Parse(format!("missing '::=' in: {line}")));
        };
        defs.push((name.trim().to_string(), body.trim_start()));
    }
    if defs.is_empty() {
        return Err(GrammarError::NoRoot);
    }
    // Root first.
    if let Some(pos) = defs.iter().position(|(n, _)| n == "root") {
        defs.swap(0, pos);
    } else {
        return Err(GrammarError::NoRoot);
    }

    let mut g = Grammar::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    for (name, _) in &defs {
        if index.contains_key(name) {
            return Err(GrammarError::Parse(format!("duplicate rule '{name}'")));
        }
        index.insert(name.clone(), g.add_rule(name.clone()));
    }

    for (name, body) in &defs {
        let rule = index[name];
        let mut p = P {
            bytes: body.as_bytes(),
            pos: 0,
            g: &mut g,
            index: &index,
            hint: name,
            budget: MAX_EXPANSION,
        };
        let alts = p.alternatives()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(GrammarError::Parse(format!(
                "trailing input in rule '{name}': {:?}",
                &body[p.pos.min(body.len())..]
            )));
        }
        for alt in alts {
            g.add_alt(rule, alt);
        }
    }
    g.validate()?;
    Ok(g)
}

/// Joins continuation lines: a line whose next line is indented continues
/// the same rule body (common GBNF formatting).
struct LogicalLines<'a> {
    lines: std::iter::Peekable<std::str::Lines<'a>>,
}

impl<'a> LogicalLines<'a> {
    fn new(text: &'a str) -> Self {
        Self { lines: text.lines().peekable() }
    }
}

impl<'a> Iterator for LogicalLines<'a> {
    type Item = String;

    fn next(&mut self) -> Option<String> {
        let mut cur = self.lines.next()?.to_string();
        loop {
            match self.lines.peek() {
                Some(next)
                    if (next.starts_with(' ') || next.starts_with('\t'))
                        && !strip_comment(next).trim().is_empty()
                        && !strip_comment(next).contains("::=") =>
                {
                    cur.push(' ');
                    cur.push_str(self.lines.next().unwrap().trim());
                }
                _ => return Some(cur),
            }
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted literal or class.
    let b = line.as_bytes();
    let mut in_str = false;
    let mut in_class = false;
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'\\' if in_str || in_class => i += 1,
            b'"' if !in_class => in_str = !in_str,
            b'[' if !in_str => in_class = true,
            b']' if !in_str => in_class = false,
            b'#' if !in_str && !in_class => return &line[..i],
            _ => {}
        }
        i += 1;
    }
    line
}

/// Largest `{m,n}` repetition count.
const MAX_REPEAT: usize = 1024;
/// Per-rule symbol-expansion budget (guards `("a"{999}){999}`).
const MAX_EXPANSION: usize = 65_536;

struct P<'a> {
    bytes: &'a [u8],
    pos: usize,
    g: &'a mut Grammar,
    index: &'a HashMap<String, usize>,
    hint: &'a str,
    budget: usize,
}

impl<'a> P<'a> {
    fn err(&self, m: impl Into<String>) -> GrammarError {
        GrammarError::Parse(format!("{} (at byte {} of rule '{}')", m.into(), self.pos, self.hint))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t')) {
            self.pos += 1;
        }
    }

    /// alternatives := sequence ('|' sequence)*
    fn alternatives(&mut self) -> Result<Vec<Vec<Sym>>, GrammarError> {
        let mut alts = vec![self.sequence()?];
        loop {
            self.skip_ws();
            if self.peek() == Some(b'|') {
                self.pos += 1;
                alts.push(self.sequence()?);
            } else {
                return Ok(alts);
            }
        }
    }

    /// sequence := (item postfix?)*
    fn sequence(&mut self) -> Result<Vec<Sym>, GrammarError> {
        let mut seq = Vec::new();
        loop {
            self.skip_ws();
            match self.peek() {
                None | Some(b'|') | Some(b')') => return Ok(seq),
                _ => {}
            }
            let item = self.item()?;
            self.skip_ws();
            match self.peek() {
                Some(b'*') => {
                    self.pos += 1;
                    let s = self.g.star(item, self.hint);
                    seq.push(s);
                }
                Some(b'+') => {
                    self.pos += 1;
                    let s = self.g.plus(item, self.hint);
                    seq.extend(s);
                }
                Some(b'?') => {
                    self.pos += 1;
                    let s = self.g.opt(item, self.hint);
                    seq.push(s);
                }
                Some(b'{') => {
                    self.pos += 1;
                    let (min, max) = self.repeat_counts()?;
                    let copies = max.unwrap_or(min) + 1;
                    let cost = item.len().max(1).saturating_mul(copies);
                    if cost > self.budget {
                        return Err(self.err("repetition expansion exceeds budget"));
                    }
                    self.budget -= cost;
                    let s = self.g.repeat(item, min, max, self.hint);
                    seq.extend(s);
                }
                _ => seq.extend(item),
            }
        }
    }

    /// item := literal | class | '(' alternatives ')' | rule-name
    fn item(&mut self) -> Result<Vec<Sym>, GrammarError> {
        match self.peek() {
            Some(b'"') => self.literal(),
            Some(b'[') => Ok(vec![Sym::Class(self.class()?)]),
            Some(b'(') => {
                self.pos += 1;
                let alts = self.alternatives()?;
                self.skip_ws();
                if self.peek() != Some(b')') {
                    return Err(self.err("expected ')'"));
                }
                self.pos += 1;
                if alts.len() == 1 {
                    Ok(alts.into_iter().next().unwrap())
                } else {
                    Ok(vec![self.g.choice(alts, self.hint)])
                }
            }
            Some(c) if c.is_ascii_alphanumeric() || c == b'_' || c == b'-' => {
                let start = self.pos;
                let is_name = |c: u8| c.is_ascii_alphanumeric() || c == b'_' || c == b'-';
                while matches!(self.peek(), Some(c) if is_name(c)) {
                    self.pos += 1;
                }
                let name = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
                match self.index.get(name) {
                    Some(&i) => Ok(vec![Sym::Ref(i)]),
                    None => Err(GrammarError::UnknownRule(name.to_string())),
                }
            }
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of rule")),
        }
    }

    /// `{m}` / `{m,}` / `{m,n}` counts; the opening `{` is consumed.
    fn repeat_counts(&mut self) -> Result<(usize, Option<usize>), GrammarError> {
        let min = self.count()?;
        let max = match self.peek() {
            Some(b'}') => Some(min),
            Some(b',') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    None
                } else {
                    Some(self.count()?)
                }
            }
            _ => return Err(self.err("expected ',' or '}' in repetition")),
        };
        if self.peek() != Some(b'}') {
            return Err(self.err("expected '}' in repetition"));
        }
        self.pos += 1;
        if min > MAX_REPEAT || max.map_or(false, |n| n > MAX_REPEAT) {
            return Err(self.err(format!("repetition count exceeds {MAX_REPEAT}")));
        }
        if let Some(n) = max {
            if n < min {
                return Err(self.err("repetition max < min"));
            }
        }
        Ok((min, max))
    }

    fn count(&mut self) -> Result<usize, GrammarError> {
        self.skip_ws();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start || self.pos - start > 7 {
            return Err(self.err("expected repetition count"));
        }
        let n: usize = std::str::from_utf8(&self.bytes[start..self.pos])
            .unwrap()
            .parse()
            .map_err(|_| self.err("bad repetition count"))?;
        self.skip_ws();
        Ok(n)
    }

    fn literal(&mut self) -> Result<Vec<Sym>, GrammarError> {
        self.pos += 1; // opening quote
        let mut bytes = Vec::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated literal")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(Grammar::lit(&bytes));
                }
                Some(b'\\') => {
                    self.pos += 1;
                    bytes.push(self.escape()?);
                }
                Some(c) => {
                    bytes.push(c);
                    self.pos += 1;
                }
            }
        }
    }

    fn class(&mut self) -> Result<ByteClass, GrammarError> {
        self.pos += 1; // '['
        let negated = if self.peek() == Some(b'^') {
            self.pos += 1;
            true
        } else {
            false
        };
        let mut ranges = Vec::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated class")),
                Some(b']') => {
                    self.pos += 1;
                    if ranges.is_empty() {
                        return Err(self.err("empty character class"));
                    }
                    return Ok(ByteClass { ranges, negated });
                }
                _ => {
                    let lo = self.class_byte()?;
                    // range?
                    if self.peek() == Some(b'-')
                        && self.bytes.get(self.pos + 1).map_or(false, |&c| c != b']')
                    {
                        self.pos += 1;
                        let hi = self.class_byte()?;
                        if hi < lo {
                            return Err(self.err("inverted range"));
                        }
                        ranges.push((lo, hi));
                    } else {
                        ranges.push((lo, lo));
                    }
                }
            }
        }
    }

    fn class_byte(&mut self) -> Result<u8, GrammarError> {
        match self.peek() {
            Some(b'\\') => {
                self.pos += 1;
                self.escape()
            }
            Some(c) => {
                self.pos += 1;
                Ok(c)
            }
            None => Err(self.err("unterminated class")),
        }
    }

    fn escape(&mut self) -> Result<u8, GrammarError> {
        let c = self.peek().ok_or_else(|| self.err("dangling escape"))?;
        self.pos += 1;
        Ok(match c {
            b'n' => b'\n',
            b't' => b'\t',
            b'r' => b'\r',
            b'\\' => b'\\',
            b'"' => b'"',
            b'\'' => b'\'',
            b'[' => b'[',
            b']' => b']',
            b'-' => b'-',
            b'^' => b'^',
            b'/' => b'/',
            b'x' => {
                let h1 = self.hex()?;
                let h2 = self.hex()?;
                h1 * 16 + h2
            }
            other => return Err(self.err(format!("unknown escape '\\{}'", other as char))),
        })
    }

    fn hex(&mut self) -> Result<u8, GrammarError> {
        let c = self.peek().ok_or_else(|| self.err("truncated \\x escape"))?;
        self.pos += 1;
        (c as char)
            .to_digit(16)
            .map(|d| d as u8)
            .ok_or_else(|| self.err("invalid hex digit"))
    }
}
