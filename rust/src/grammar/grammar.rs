//! Byte-level CFG intermediate representation.
//!
//! Normal form: every rule is a list of alternatives; every alternative a
//! flat sequence of symbols; a symbol is a byte-class terminal or a rule
//! reference. Repetition sugar (`* + ?`) from the EBNF/schema frontends
//! is desugared into fresh right-recursive rules at construction time.

use std::fmt;

/// A set of byte ranges, possibly negated ("any byte not in ranges").
#[derive(Clone, Debug, PartialEq)]
pub struct ByteClass {
    pub ranges: Vec<(u8, u8)>, // inclusive
    pub negated: bool,
}

impl ByteClass {
    /// The singleton class matching exactly `b`.
    pub fn byte(b: u8) -> Self {
        Self { ranges: vec![(b, b)], negated: false }
    }

    /// Whether `b` is in the class (negation applied).
    pub fn matches(&self, b: u8) -> bool {
        let inside = self.ranges.iter().any(|&(lo, hi)| lo <= b && b <= hi);
        inside != self.negated
    }
}

/// One grammar symbol.
#[derive(Clone, Debug, PartialEq)]
pub enum Sym {
    /// Terminal: one byte matching the class.
    Class(ByteClass),
    /// Nonterminal reference.
    Ref(usize),
}

/// A rule: alternatives of symbol sequences.
#[derive(Clone, Debug, Default)]
pub struct Rule {
    pub name: String,
    pub alts: Vec<Vec<Sym>>,
}

/// Errors from the grammar frontends and validation.
#[derive(Debug, Clone, PartialEq)]
pub enum GrammarError {
    /// A rule reference names a rule that is not defined.
    UnknownRule(String),
    /// The grammar defines no `root` rule (or no rules at all).
    NoRoot,
    /// EBNF syntax error (message includes rule and byte offset).
    Parse(String),
    /// JSON-Schema compilation error (unsupported or contradictory
    /// keywords).
    Schema(String),
}

impl fmt::Display for GrammarError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GrammarError::UnknownRule(r) => write!(f, "unknown rule '{r}'"),
            GrammarError::NoRoot => write!(f, "grammar has no 'root' rule"),
            GrammarError::Parse(m) => write!(f, "grammar parse error: {m}"),
            GrammarError::Schema(m) => write!(f, "json-schema error: {m}"),
        }
    }
}

impl std::error::Error for GrammarError {}

/// A compiled grammar. Rule 0 is always the root.
#[derive(Clone, Debug, Default)]
pub struct Grammar {
    pub rules: Vec<Rule>,
}

impl Grammar {
    /// An empty grammar (add a `root` rule before use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an empty rule, returning its index.
    pub fn add_rule(&mut self, name: impl Into<String>) -> usize {
        self.rules.push(Rule { name: name.into(), alts: Vec::new() });
        self.rules.len() - 1
    }

    /// Index of the rule named `name`, if any.
    pub fn rule_index(&self, name: &str) -> Option<usize> {
        self.rules.iter().position(|r| r.name == name)
    }

    /// Append an alternative to a rule.
    pub fn add_alt(&mut self, rule: usize, alt: Vec<Sym>) {
        self.rules[rule].alts.push(alt);
    }

    /// Helper: a literal byte string as a symbol sequence.
    pub fn lit(s: &[u8]) -> Vec<Sym> {
        s.iter().map(|&b| Sym::Class(ByteClass::byte(b))).collect()
    }

    /// Desugar `inner*` into a fresh rule R -> inner R | ε, returning Ref(R).
    pub fn star(&mut self, inner: Vec<Sym>, hint: &str) -> Sym {
        let r = self.add_rule(format!("{hint}*"));
        let mut alt = inner;
        alt.push(Sym::Ref(r));
        self.add_alt(r, alt);
        self.add_alt(r, Vec::new());
        Sym::Ref(r)
    }

    /// Desugar `inner+` into inner inner*.
    pub fn plus(&mut self, inner: Vec<Sym>, hint: &str) -> Vec<Sym> {
        let star = self.star(inner.clone(), hint);
        let mut seq = inner;
        seq.push(star);
        seq
    }

    /// Desugar bounded repetition `inner{min,max}` into a symbol sequence:
    /// `min` mandatory copies followed by `max - min` nested optionals
    /// (`(inner (inner ...)?)?`), or a trailing star when `max` is `None`.
    /// Callers must validate `max >= min`; a smaller `max` yields just the
    /// mandatory prefix.
    pub fn repeat(
        &mut self,
        inner: Vec<Sym>,
        min: usize,
        max: Option<usize>,
        hint: &str,
    ) -> Vec<Sym> {
        let mut seq = Vec::new();
        for _ in 0..min {
            seq.extend(inner.iter().cloned());
        }
        match max {
            None => seq.push(self.star(inner, hint)),
            Some(max) => {
                let mut tail: Option<Sym> = None;
                for _ in min..max {
                    let mut v = inner.clone();
                    if let Some(t) = tail.take() {
                        v.push(t);
                    }
                    tail = Some(self.opt(v, hint));
                }
                if let Some(t) = tail {
                    seq.push(t);
                }
            }
        }
        seq
    }

    /// Desugar `inner?` into a fresh rule R -> inner | ε.
    pub fn opt(&mut self, inner: Vec<Sym>, hint: &str) -> Sym {
        let r = self.add_rule(format!("{hint}?"));
        self.add_alt(r, inner);
        self.add_alt(r, Vec::new());
        Sym::Ref(r)
    }

    /// Wrap alternatives into a single referencable rule.
    pub fn choice(&mut self, alts: Vec<Vec<Sym>>, hint: &str) -> Sym {
        let r = self.add_rule(format!("{hint}|"));
        for a in alts {
            self.add_alt(r, a);
        }
        Sym::Ref(r)
    }

    /// Validate: all refs in range, root exists and is rule 0.
    pub fn validate(&self) -> Result<(), GrammarError> {
        if self.rules.is_empty() {
            return Err(GrammarError::NoRoot);
        }
        for rule in &self.rules {
            for alt in &rule.alts {
                for sym in alt {
                    if let Sym::Ref(i) = sym {
                        if *i >= self.rules.len() {
                            return Err(GrammarError::UnknownRule(format!("#{i}")));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Whether `rule` can derive the empty string (used by the matcher's
    /// epsilon closure and by tests).
    pub fn nullable(&self) -> Vec<bool> {
        let n = self.rules.len();
        let mut nullable = vec![false; n];
        let mut changed = true;
        while changed {
            changed = false;
            for (i, rule) in self.rules.iter().enumerate() {
                if nullable[i] {
                    continue;
                }
                let can = rule.alts.iter().any(|alt| {
                    alt.iter().all(|s| match s {
                        Sym::Class(_) => false,
                        Sym::Ref(r) => nullable[*r],
                    })
                });
                if can {
                    nullable[i] = true;
                    changed = true;
                }
            }
        }
        nullable
    }
}
