//! Structured generation engine (the paper's XGrammar-in-WASM subsystem,
//! §2.1/§2.2 — here in native Rust).
//!
//! Pipeline:
//!   * a grammar arrives as GBNF-style EBNF text (`ebnf`) or is compiled
//!     from a JSON Schema (`json_schema`), producing the byte-level CFG
//!     IR in `grammar`;
//!   * `matcher` runs the grammar as a pushdown automaton over a *set* of
//!     stacks (nondeterminism), advancing one byte at a time;
//!   * per decode step the matcher produces a vocabulary bitmask for the
//!     sampler (`GrammarMatcher::token_mask`), with an adaptive mask
//!     cache keyed by the automaton state fingerprint — the XGrammar
//!     "context-independent tokens" precomputation, adapted.
//!
//! The engine applies the mask in `sampler::LogitsProcessor::sample`, and
//! `accept_token` advances the automaton with whatever was sampled.

mod ebnf;
mod grammar;
mod json_schema;
mod matcher;

pub use ebnf::parse_ebnf;
pub use grammar::{Grammar, GrammarError, Sym};
pub use json_schema::schema_to_grammar;
pub use matcher::{GrammarMatcher, MaskCache, VocabTrie};

#[cfg(test)]
mod tests;
