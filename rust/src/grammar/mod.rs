//! Structured generation engine (the paper's XGrammar-in-WASM subsystem,
//! §2.1/§2.2 — here in native Rust).
//!
//! Pipeline:
//!   * a grammar arrives as GBNF-style EBNF text (`ebnf`) or is compiled
//!     from a JSON Schema (`json_schema`), producing the byte-level CFG
//!     IR in `grammar`;
//!   * `matcher` runs the grammar as a pushdown automaton over a *set* of
//!     stacks (nondeterminism), advancing one byte at a time;
//!   * per decode step the matcher produces a packed vocabulary bitmask
//!     ([`TokenBitmask`], one `u64` word per 64 tokens) for the sampler
//!     (`GrammarMatcher::token_mask`), with an adaptive mask cache keyed
//!     by the automaton state fingerprint — the XGrammar
//!     "context-independent tokens" precomputation, adapted. Cache hits
//!     hand out `Rc<TokenBitmask>` clones, so the steady-state per-token
//!     cost of constrained decoding is a hash lookup + pointer bump.
//!
//! The engine applies the mask in
//! `sampler::LogitsProcessor::sample_masked`, which walks the packed words
//! directly (skipping 64 banned tokens per zero word), and `accept_token`
//! advances the automaton with whatever was sampled.

mod bitmask;
mod ebnf;
mod grammar;
mod json_schema;
mod matcher;

pub use bitmask::TokenBitmask;
pub use ebnf::parse_ebnf;
pub use grammar::{Grammar, GrammarError, Sym};
pub use json_schema::schema_to_grammar;
pub use matcher::{GrammarMatcher, MaskCache, VocabTrie};

#[cfg(test)]
mod tests;
