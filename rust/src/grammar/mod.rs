//! Structured generation engine (the paper's XGrammar-in-WASM subsystem,
//! §2.1/§2.2 — here in native Rust).
//!
//! Pipeline:
//!   * a grammar arrives as GBNF-style EBNF text ([`parse_ebnf`]) or is
//!     compiled from a JSON Schema ([`schema_to_grammar`]), producing the
//!     byte-level CFG IR in `grammar`;
//!   * `compiler` runs once per (grammar, vocabulary): it walks the
//!     vocabulary trie against the grammar's byte structure and
//!     partitions tokens into context-*independent* sets — always
//!     accepted / always rejected regardless of matcher state, XGrammar's
//!     compile-time adaptive-mask analysis — plus a context-dependent
//!     residue, emitting a [`CompiledGrammar`];
//!   * `matcher` runs the grammar as a pushdown automaton over a *set* of
//!     stacks (nondeterminism), advancing one byte at a time;
//!   * per decode step the engine asks the [`MaskCache`] for the packed
//!     vocabulary bitmask ([`TokenBitmask`], one `u64` word per 64
//!     tokens) of the current automaton state: a hit is an
//!     `Rc<TokenBitmask>` pointer clone; a miss trie-walks only the
//!     residue and ORs the precomputed base mask. Eviction is a
//!     capacity-bounded LRU keyed by the state fingerprint, so the
//!     steady-state per-token cost of constrained decoding is a hash
//!     lookup + pointer bump.
//!
//! The engine applies the mask in
//! `sampler::LogitsProcessor::sample_masked`, which walks the packed words
//! directly (skipping 64 banned tokens per zero word), and `accept_token`
//! advances the automaton with whatever was sampled.

mod bitmask;
mod compiler;
mod ebnf;
mod grammar;
mod json_schema;
mod matcher;
mod regex;

pub use bitmask::TokenBitmask;
pub use compiler::CompiledGrammar;
pub use ebnf::parse_ebnf;
pub use grammar::{Grammar, GrammarError, Sym};
pub use json_schema::{format_pattern, schema_to_grammar};
pub use matcher::{GrammarMatcher, MaskCache, MaskCacheCounters, VocabTrie};
pub use regex::regex_to_grammar;

#[cfg(test)]
mod tests;
