use super::compiler::reachable_states;
use super::*;
use crate::json::parse;
use crate::testutil::prop::Runner;
use std::rc::Rc;

fn matcher(g: Grammar) -> GrammarMatcher {
    GrammarMatcher::new(Rc::new(g))
}

fn accepts(g: &Rc<Grammar>, input: &str) -> bool {
    let mut m = GrammarMatcher::new(g.clone());
    m.advance_bytes(input.as_bytes()) && m.is_accepting()
}

fn rejects_prefix(g: &Rc<Grammar>, input: &str) -> bool {
    let mut m = GrammarMatcher::new(g.clone());
    !m.advance_bytes(input.as_bytes())
}

// -- EBNF parsing -----------------------------------------------------------

#[test]
fn ebnf_literal_and_alternation() {
    let g = Rc::new(parse_ebnf(r#"root ::= "yes" | "no""#).unwrap());
    assert!(accepts(&g, "yes"));
    assert!(accepts(&g, "no"));
    assert!(!accepts(&g, "ye"));
    assert!(rejects_prefix(&g, "maybe"));
}

#[test]
fn ebnf_classes_and_repetition() {
    let g = Rc::new(parse_ebnf("root ::= [a-z]+ [0-9]*").unwrap());
    assert!(accepts(&g, "abc"));
    assert!(accepts(&g, "abc123"));
    assert!(!accepts(&g, ""));
    assert!(rejects_prefix(&g, "1abc"));
}

#[test]
fn ebnf_groups_optional_refs() {
    let text = r#"
root ::= greeting (" " name)?
greeting ::= "hi" | "hello"
name ::= [A-Z] [a-z]*
"#;
    let g = Rc::new(parse_ebnf(text).unwrap());
    assert!(accepts(&g, "hi"));
    assert!(accepts(&g, "hello Bob"));
    assert!(!accepts(&g, "hello "));
    assert!(rejects_prefix(&g, "hello bob"));
}

#[test]
fn ebnf_escapes_and_comments() {
    let text = "root ::= \"a\\nb\" [\\x30-\\x39]+  # trailing comment\n";
    let g = Rc::new(parse_ebnf(text).unwrap());
    assert!(accepts(&g, "a\nb42"));
    assert!(!accepts(&g, "a\nb"));
}

#[test]
fn ebnf_negated_class() {
    let g = Rc::new(parse_ebnf(r#"root ::= "\"" [^"]* "\"""#).unwrap());
    assert!(accepts(&g, "\"anything but quotes\""));
    assert!(!accepts(&g, "\"unclosed"));
}

#[test]
fn ebnf_errors() {
    assert!(matches!(parse_ebnf(""), Err(GrammarError::NoRoot)));
    assert!(matches!(parse_ebnf("foo ::= \"x\""), Err(GrammarError::NoRoot)));
    assert!(matches!(
        parse_ebnf("root ::= bar"),
        Err(GrammarError::UnknownRule(_))
    ));
    assert!(parse_ebnf("root ::= \"unterminated").is_err());
    assert!(parse_ebnf("root ::= []").is_err());
    assert!(parse_ebnf("root ::= \"a\"\nroot ::= \"b\"").is_err());
}

#[test]
fn ebnf_recursive_rule_balanced_parens() {
    let text = r#"
root ::= expr
expr ::= "(" expr ")" | "x"
"#;
    let g = Rc::new(parse_ebnf(text).unwrap());
    assert!(accepts(&g, "x"));
    assert!(accepts(&g, "((x))"));
    assert!(!accepts(&g, "((x)"));
    assert!(rejects_prefix(&g, ")"));
}

// -- matcher mechanics ------------------------------------------------------

#[test]
fn matcher_accepting_state_transitions() {
    let g = Rc::new(parse_ebnf(r#"root ::= "ab" "c"?"#).unwrap());
    let mut m = GrammarMatcher::new(g);
    assert!(!m.is_accepting());
    assert!(m.advance(b'a'));
    assert!(!m.is_accepting());
    assert!(m.advance(b'b'));
    assert!(m.is_accepting(), "ab is complete");
    assert!(m.advance(b'c'));
    assert!(m.is_accepting(), "abc is complete too");
    assert!(!m.advance(b'c'), "abcc rejected");
    assert!(m.is_dead());
}

#[test]
fn matcher_token_mask_restricts_vocab() {
    let g = Rc::new(parse_ebnf(r#"root ::= "yes" | "no""#).unwrap());
    let m = GrammarMatcher::new(g);
    let vocab: Vec<&[u8]> = vec![b"y", b"n", b"yes", b"no", b"x", b"ye", b"yn", b""];
    let mask = m.token_mask(vocab.len(), |i| vocab[i as usize]);
    assert_eq!(mask.to_bools(), vec![true, true, true, true, false, true, false, false]);
    assert_eq!(mask.count_allowed(), 5);
}

#[test]
fn matcher_mask_evolves_with_state() {
    let g = Rc::new(parse_ebnf(r#"root ::= "yes" | "no""#).unwrap());
    let mut m = GrammarMatcher::new(g);
    m.advance(b'y');
    let vocab: Vec<&[u8]> = vec![b"e", b"es", b"o", b"n"];
    let mask = m.token_mask(vocab.len(), |i| vocab[i as usize]);
    assert_eq!(mask.to_bools(), vec![true, true, false, false]);
}

#[test]
fn matcher_fingerprint_stable_and_state_dependent() {
    let g = Rc::new(parse_ebnf("root ::= [a-z]+").unwrap());
    let m1 = GrammarMatcher::new(g.clone());
    let m2 = GrammarMatcher::new(g.clone());
    assert_eq!(m1.fingerprint(), m2.fingerprint());
    let mut m3 = GrammarMatcher::new(g);
    m3.advance(b'q');
    // [a-z]+ after one char: state differs from start (can now end).
    assert_ne!(m1.fingerprint(), m3.fingerprint());
}

fn compiled(g: &Rc<Grammar>, vocab: &'static [&'static [u8]]) -> Rc<CompiledGrammar> {
    let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize]);
    Rc::new(CompiledGrammar::compile(g.clone(), &trie, |i| vocab[i as usize]))
}

#[test]
fn mask_cache_hits_on_repeated_states() {
    let g = Rc::new(parse_ebnf("root ::= [a-z]+").unwrap());
    let mut m = GrammarMatcher::new(g.clone());
    static VOCAB: &[&[u8]] = &[b"a", b"bc", b"1"];
    let mut cache = MaskCache::new(compiled(&g, VOCAB), 64);
    let _ = cache.get_or_compute(&m);
    m.advance(b'a');
    let _ = cache.get_or_compute(&m);
    m.advance(b'b'); // same automaton state as after 'a'
    let mask = cache.get_or_compute(&m);
    assert_eq!(mask.to_bools(), vec![true, true, false]);
    let (hits, misses) = cache.stats();
    assert_eq!(hits, 1);
    assert_eq!(misses, 2);
}

#[test]
fn mask_cache_hit_is_pointer_clone() {
    // The O(1)-hit contract: repeated visits to the same automaton state
    // return the *same* Rc allocation, not a vocab-sized copy.
    let g = Rc::new(parse_ebnf("root ::= [a-z]+").unwrap());
    let mut m = GrammarMatcher::new(g.clone());
    static VOCAB: &[&[u8]] = &[b"a", b"bc", b"1"];
    let mut cache = MaskCache::new(compiled(&g, VOCAB), 64);
    m.advance(b'a');
    let first = cache.get_or_compute(&m);
    m.advance(b'z'); // [a-z]+ loops: same automaton state
    let second = cache.get_or_compute(&m);
    assert!(Rc::ptr_eq(&first, &second), "cache hit must be an Rc clone");
}

#[test]
fn mask_cache_lru_eviction_is_deterministic() {
    // Capacity 2, three distinct automaton states: the least-recently-
    // used entry (and only it) must go, with the recency order decided by
    // accesses, not hash order.
    let g = Rc::new(parse_ebnf(r#"root ::= "abc" [0-9]+"#).unwrap());
    static VOCAB: &[&[u8]] = &[b"a", b"b", b"c", b"1", b"ab"];
    let mut cache = MaskCache::new(compiled(&g, VOCAB), 2);

    let m0 = GrammarMatcher::new(g.clone());
    let mut m1 = m0.clone();
    assert!(m1.advance(b'a'));
    let mut m2 = m1.clone();
    assert!(m2.advance(b'b'));
    assert_ne!(m0.fingerprint(), m1.fingerprint());
    assert_ne!(m1.fingerprint(), m2.fingerprint());

    let _ = cache.get_or_compute(&m0); // miss, insert {m0}
    let _ = cache.get_or_compute(&m1); // miss, insert {m0, m1}
    let a = cache.get_or_compute(&m0); // hit: m0 now more recent than m1
    let _ = cache.get_or_compute(&m2); // miss at capacity: evicts m1 (LRU)
    let b = cache.get_or_compute(&m0); // m0 must have survived
    assert!(Rc::ptr_eq(&a, &b), "m0 evicted despite being recently used");

    let c = cache.counters();
    assert_eq!((c.hits, c.misses, c.evictions), (2, 3, 1));
    assert_eq!((c.entries, c.capacity), (2, 2));

    let _ = cache.get_or_compute(&m1); // recompute: evicts m2 (older than m0)
    let d = cache.get_or_compute(&m0); // still resident
    assert!(Rc::ptr_eq(&a, &d));
    let c = cache.counters();
    assert_eq!((c.hits, c.misses, c.evictions), (3, 4, 2));
}

#[test]
fn trie_mask_matches_per_token_mask() {
    // The arena-DFS trie walk and the straight per-token simulation must
    // produce identical masks at every state along a derivation.
    let g = Rc::new(parse_ebnf(r#"root ::= ("ab" | "ac" | "b" [0-9]+)+"#).unwrap());
    let vocab: Vec<&[u8]> =
        vec![b"a", b"b", b"ab", b"ac", b"abc", b"b1", b"12", b"1", b"c", b"", b"zz"];
    let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize]);
    let mut m = GrammarMatcher::new(g);
    for &b in b"abb12ac" {
        let flat = m.token_mask(vocab.len(), |i| vocab[i as usize]);
        let fast = m.token_mask_trie(&trie);
        assert_eq!(flat.to_bools(), fast.to_bools(), "diverged before byte {}", b as char);
        assert!(m.advance(b), "grammar rejected test input at {}", b as char);
    }
}

// -- ahead-of-time compiler (context-independent token analysis) --------------

/// Artifact-free vocabulary with realistic byte spread: every single
/// byte (so control bytes and invalid UTF-8 are represented, token id ==
/// byte value), then a mix of JSON-ish and junk multi-byte strings.
fn aot_test_vocab() -> Vec<Vec<u8>> {
    let mut v: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
    for s in [
        &b"ab"[..],
        b"cd",
        b"abab",
        b"abc",
        b"{\"",
        b"\":",
        b"\",\"",
        b"true",
        b"false",
        b"null",
        b"12",
        b"3.5",
        b"-7",
        b"\"x\"",
        b"name",
        b"count",
        b"ok",
        b"}]",
        b"\\\"",
        b"\\u0041",
        b"zz",
        b"((x",
        b"))",
        b"\n\n",
        b"\x01\x02",
        b"\xff\xfe",
        b"\xc3\xa9", // e-acute, valid UTF-8
        b"\xe2\x82\xac", // euro sign
    ] {
        v.push(s.to_vec());
    }
    v.push(Vec::new()); // an empty special: never grammar-eligible
    v
}

fn aot_test_grammars() -> Vec<(&'static str, Rc<Grammar>)> {
    vec![
        ("ebnf-pairs", Rc::new(parse_ebnf(r#"root ::= ("ab" | "cd")+ [0-9] [0-9]?"#).unwrap())),
        ("ebnf-letters", Rc::new(parse_ebnf("root ::= [a-z]+").unwrap())),
        (
            "ebnf-parens",
            Rc::new(parse_ebnf("root ::= expr\nexpr ::= \"(\" expr \")\" | \"x\"").unwrap()),
        ),
        (
            "schema-object",
            schema(
                r#"{
                "type": "object",
                "properties": {
                    "name": {"type": "string"},
                    "count": {"type": "integer"},
                    "ok": {"type": "boolean"}
                },
                "required": ["name", "count", "ok"]
            }"#,
            ),
        ),
        (
            "schema-recursive",
            schema(
                r##"{
                "$defs": {
                    "node": {
                        "type": "object",
                        "properties": {
                            "v": {"type": "integer"},
                            "next": {"anyOf": [{"$ref": "#/$defs/node"}, {"type": "null"}]}
                        },
                        "required": ["v", "next"]
                    }
                },
                "$ref": "#/$defs/node"
            }"##,
            ),
        ),
        ("schema-any", schema("{}")),
    ]
}

#[test]
fn prop_compiled_base_plus_residue_equals_full_walk() {
    // The compiler's contract, token for token: for every reachable
    // automaton state, `base_accept ∪ residue-walk(state)` must equal the
    // whole-vocabulary trie walk. Finite grammars are checked on *all*
    // reachable states; unboundedly recursive ones on the first 150
    // states of the byte-level BFS.
    let vocab = aot_test_vocab();
    let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize].as_slice());
    for (name, g) in aot_test_grammars() {
        let c = CompiledGrammar::compile(g.clone(), &trie, |i| vocab[i as usize].as_slice());
        assert!(
            c.base_accept().is_disjoint(c.base_reject()),
            "{name}: base sets overlap"
        );
        assert_eq!(
            c.base_accept().count_allowed() + c.base_reject().count_allowed() + c.residue().len(),
            vocab.len(),
            "{name}: partition must cover the vocabulary exactly"
        );
        let reached = reachable_states(&g, 150);
        assert!(!reached.states.is_empty(), "{name}: no states");
        for state in &reached.states {
            let full = state.token_mask_trie(&trie);
            let fast = c.mask_for(state);
            assert_eq!(
                full.to_bools(),
                fast.to_bools(),
                "{name}: mask diverged at state {:x} (exact={}, complete_bfs={})",
                state.fingerprint(),
                c.is_exact(),
                reached.complete,
            );
        }
    }
}

#[test]
fn compiled_schema_classifies_impossible_bytes_as_context_independent() {
    // JSON grammars never consume raw control bytes (strings require
    // escapes), so those single-byte tokens must be always-rejected —
    // the nonzero context-independent fraction the bench reports.
    let vocab = aot_test_vocab();
    let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize].as_slice());
    for (name, g) in aot_test_grammars() {
        let c = CompiledGrammar::compile(g, &trie, |i| vocab[i as usize].as_slice());
        for ctl in [0x00usize, 0x0A, 0x1F] {
            assert!(
                c.base_reject().is_allowed(ctl),
                "{name}: control byte {ctl:#x} should be always-rejected"
            );
        }
        // Empty-byte specials are never grammar-eligible.
        assert!(c.base_reject().is_allowed(vocab.len() - 1), "{name}: empty token");
        assert!(
            c.context_independent_fraction() > 0.0,
            "{name}: expected a nonzero context-independent fraction"
        );
    }
}

#[test]
fn compiled_loop_grammar_has_exact_nonempty_base_accept() {
    // `[a-z]+` has two reachable states and every lowercase token is
    // acceptable in both: the exact analysis must find a nonempty
    // always-accepted set, and the residue walk must stay correct.
    let vocab = aot_test_vocab();
    let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize].as_slice());
    let g = Rc::new(parse_ebnf("root ::= [a-z]+").unwrap());
    let c = CompiledGrammar::compile(g.clone(), &trie, |i| vocab[i as usize].as_slice());
    assert!(c.is_exact(), "[a-z]+ is finite-state");
    assert_eq!(c.states_explored(), 2);
    assert!(c.base_accept().is_allowed(b'a' as usize));
    let zz = vocab.iter().position(|t| t == b"zz").unwrap();
    assert!(c.base_accept().is_allowed(zz));
    assert!(c.base_reject().is_allowed(b'0' as usize));
    // With everything classified, the residue (and its trie) are empty
    // and a mask is assembled without stepping the automaton at all.
    assert!(c.residue().is_empty());
    let mask = c.mask_for(&GrammarMatcher::new(g));
    assert_eq!(mask.count_allowed(), c.base_accept().count_allowed());
}

#[test]
fn compiled_recursive_grammar_falls_back_to_sound_approximation() {
    // Unbounded nesting defeats exact state enumeration; the NFA
    // fallback must report inexactness, an empty base_accept, and a
    // base_reject that still catches never-consumable tokens.
    let vocab = aot_test_vocab();
    let trie = VocabTrie::build(vocab.len(), |i| vocab[i as usize].as_slice());
    let g = Rc::new(parse_ebnf("root ::= expr\nexpr ::= \"(\" expr \")\" | \"x\"").unwrap());
    let c = CompiledGrammar::compile(g, &trie, |i| vocab[i as usize].as_slice());
    assert!(!c.is_exact(), "balanced parens are not finite-state");
    assert!(!c.base_accept().any_allowed());
    assert!(c.base_reject().is_allowed(b'z' as usize), "'z' never appears");
    let open = vocab.iter().position(|t| t == b"((x").unwrap();
    assert!(
        !c.base_reject().is_allowed(open),
        "\"((x\" is consumable from the start state"
    );
}

// -- JSON-Schema compilation --------------------------------------------------

fn schema(s: &str) -> Rc<Grammar> {
    Rc::new(schema_to_grammar(&parse(s).unwrap()).unwrap())
}

#[test]
fn schema_string() {
    let g = schema(r#"{"type": "string"}"#);
    assert!(accepts(&g, "\"hello\""));
    assert!(accepts(&g, "\"esc \\\" ok\""));
    assert!(accepts(&g, "\"uni \\u00e9\""));
    assert!(!accepts(&g, "\"open"));
    assert!(rejects_prefix(&g, "42"));
}

#[test]
fn schema_numbers() {
    let g = schema(r#"{"type": "number"}"#);
    for ok in ["0", "-1", "3.25", "1e9", "-2.5E-3", "42"] {
        assert!(accepts(&g, ok), "{ok}");
    }
    for bad in ["01", "+1", ".5", "1."] {
        let mut m = GrammarMatcher::new(g.clone());
        let fed = m.advance_bytes(bad.as_bytes());
        assert!(!(fed && m.is_accepting()), "{bad} wrongly accepted");
    }
    let g = schema(r#"{"type": "integer"}"#);
    assert!(accepts(&g, "-17"));
    assert!(!accepts(&g, "1.5"));
}

#[test]
fn schema_enum_and_const() {
    let g = schema(r#"{"enum": ["red", "green", 3, true]}"#);
    assert!(accepts(&g, "\"red\""));
    assert!(accepts(&g, "3"));
    assert!(accepts(&g, "true"));
    assert!(!accepts(&g, "\"blue\""));
    let g = schema(r#"{"const": {"k": 1}}"#);
    assert!(accepts(&g, "{\"k\":1}"));
}

#[test]
fn schema_object_required_and_optional() {
    let g = schema(
        r#"{
        "type": "object",
        "properties": {
            "name": {"type": "string"},
            "age": {"type": "integer"},
            "tag": {"type": "string"}
        },
        "required": ["name"]
    }"#,
    );
    assert!(accepts(&g, r#"{"name":"bo"}"#));
    assert!(accepts(&g, r#"{"name":"bo","age":4}"#));
    assert!(accepts(&g, r#"{"name":"bo","age":4,"tag":"x"}"#));
    assert!(accepts(&g, r#"{"name":"bo","tag":"x"}"#));
    // missing required
    assert!(!accepts(&g, r#"{"age":4}"#));
    // property order is fixed (schema order) in the compact canon
    assert!(!accepts(&g, r#"{"age":4,"name":"bo"}"#));
    // no whitespace in canon
    assert!(!accepts(&g, r#"{ "name":"bo"}"#));
}

#[test]
fn schema_array_bounds() {
    let g = schema(r#"{"type": "array", "items": {"type": "integer"}}"#);
    assert!(accepts(&g, "[]"));
    assert!(accepts(&g, "[1,2,3]"));
    assert!(!accepts(&g, "[1,]"));
    let g = schema(r#"{"type":"array","items":{"type":"integer"},"minItems":1,"maxItems":3}"#);
    assert!(!accepts(&g, "[]"));
    assert!(accepts(&g, "[1]"));
    assert!(accepts(&g, "[1,2,3]"));
    assert!(!accepts(&g, "[1,2,3,4]"));
    let g = schema(r#"{"type":"array","items":{"type":"integer"},"maxItems":2}"#);
    assert!(accepts(&g, "[]"));
    assert!(accepts(&g, "[5,6]"));
    assert!(!accepts(&g, "[5,6,7]"));
}

#[test]
fn schema_nested_and_anyof() {
    let g = schema(
        r#"{
        "type": "object",
        "properties": {
            "id": {"anyOf": [{"type": "integer"}, {"type": "string"}]},
            "tags": {"type": "array", "items": {"type": "string"}}
        },
        "required": ["id", "tags"]
    }"#,
    );
    assert!(accepts(&g, r#"{"id":7,"tags":["a","b"]}"#));
    assert!(accepts(&g, r#"{"id":"x7","tags":[]}"#));
    assert!(!accepts(&g, r#"{"id":null,"tags":[]}"#));
}

#[test]
fn schema_refs_and_recursion() {
    let g = schema(
        r##"{
        "$defs": {
            "node": {
                "type": "object",
                "properties": {
                    "v": {"type": "integer"},
                    "next": {"anyOf": [{"$ref": "#/$defs/node"}, {"type": "null"}]}
                },
                "required": ["v", "next"]
            }
        },
        "$ref": "#/$defs/node"
    }"##,
    );
    assert!(accepts(&g, r#"{"v":1,"next":null}"#));
    assert!(accepts(&g, r#"{"v":1,"next":{"v":2,"next":null}}"#));
    assert!(!accepts(&g, r#"{"v":1}"#));
}

#[test]
fn schema_free_value() {
    let g = schema("{}");
    for ok in ["null", "true", "[1,\"x\",{}]", "{\"a\":[false]}", "-3.5e2"] {
        assert!(accepts(&g, ok), "{ok}");
    }
    assert!(!accepts(&g, "nope"));
}

#[test]
fn schema_errors() {
    for bad in [
        r#"{"type": "banana"}"#,
        r#"{"enum": []}"#,
        r#"{"type":"object","properties":{"a":{"type":"string"}},"required":["b"]}"#,
        r##"{"$ref": "#/nope/x"}"##,
        r#"{"type":"array","minItems":3,"maxItems":1}"#,
    ] {
        assert!(schema_to_grammar(&parse(bad).unwrap()).is_err(), "{bad}");
    }
}

// -- end-to-end masked generation property ------------------------------------

#[test]
fn prop_masked_generation_always_yields_valid_json() {
    // Walk the automaton with random mask-respecting choices over the real
    // artifact vocabulary; the result must parse and satisfy the schema
    // shape. This is the core guarantee structured generation sells.
    let Some(tok) = crate::tokenizer::tests::artifact_tokenizer() else { return };
    let schema_text = r#"{
        "type": "object",
        "properties": {
            "name": {"type": "string"},
            "count": {"type": "integer"},
            "ok": {"type": "boolean"}
        },
        "required": ["name", "count", "ok"]
    }"#;
    let g = Rc::new(schema_to_grammar(&parse(schema_text).unwrap()).unwrap());
    let vocab = tok.vocab_size();
    let trie = VocabTrie::build(vocab, |i| tok.token_bytes(i));
    Runner::new("masked_generation", 15).run(|rng| {
        let mut m = GrammarMatcher::new(g.clone());
        let mut out: Vec<u8> = Vec::new();
        for _step in 0..400 {
            if m.is_accepting() && rng.range(4) == 0 {
                break; // "sample EOS"
            }
            let mask = m.token_mask_trie(&trie);
            let allowed: Vec<u32> =
                (0..vocab as u32).filter(|&i| mask[i as usize]).collect();
            if allowed.is_empty() {
                if m.is_accepting() {
                    break;
                }
                return Err(format!(
                    "stuck: no allowed token, output so far {:?}",
                    String::from_utf8_lossy(&out)
                ));
            }
            let t = *rng.choose(&allowed);
            out.extend_from_slice(tok.token_bytes(t));
            if !m.accept_token(tok.token_bytes(t)) {
                return Err("masked token rejected by matcher".into());
            }
        }
        if !m.is_accepting() {
            // ran out of steps mid-derivation; not an error, just skip
            return Ok(());
        }
        let text = String::from_utf8(out).map_err(|e| e.to_string())?;
        let v = parse(&text).map_err(|e| format!("invalid JSON {text:?}: {e}"))?;
        for key in ["name", "count", "ok"] {
            if v.get(key).is_none() {
                return Err(format!("missing {key} in {text}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ebnf_masked_generation_matches_grammar() {
    let Some(tok) = crate::tokenizer::tests::artifact_tokenizer() else { return };
    let g = Rc::new(
        parse_ebnf(r#"root ::= ("ab" | "cd")+ [0-9] [0-9]?"#).unwrap(),
    );
    let vocab = tok.vocab_size();
    let trie = VocabTrie::build(vocab, |i| tok.token_bytes(i));
    Runner::new("ebnf_generation", 25).run(|rng| {
        let mut m = GrammarMatcher::new(g.clone());
        let mut out = Vec::new();
        for _ in 0..60 {
            if m.is_accepting() && rng.bool() {
                break;
            }
            let mask = m.token_mask_trie(&trie);
            let allowed: Vec<u32> =
                (0..vocab as u32).filter(|&i| mask[i as usize]).collect();
            if allowed.is_empty() {
                break;
            }
            let t = *rng.choose(&allowed);
            out.extend_from_slice(tok.token_bytes(t));
            m.accept_token(tok.token_bytes(t));
        }
        if !m.is_accepting() {
            return Ok(());
        }
        let s = String::from_utf8(out).unwrap();
        // shape check: (ab|cd)+ then 1-2 digits
        let body_len = s.len() - s.chars().rev().take_while(|c| c.is_ascii_digit()).count();
        let (body, digits) = s.split_at(body_len);
        if body.is_empty() || body.len() % 2 != 0 {
            return Err(format!("bad body {s:?}"));
        }
        if !(1..=2).contains(&digits.len()) {
            return Err(format!("bad digits {s:?}"));
        }
        for chunk in body.as_bytes().chunks(2) {
            if chunk != b"ab" && chunk != b"cd" {
                return Err(format!("bad chunk in {s:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_schemaless_grammar_accepts_all_serializer_output() {
    // Cross-validation: anything crate::json can serialize must be
    // accepted by the empty-schema ("any JSON value") grammar — the two
    // independent JSON implementations must agree on the language.
    use crate::json::{to_string, Map, Value};
    let g = Rc::new(schema_to_grammar(&parse("{}").unwrap()).unwrap());
    fn arbitrary(rng: &mut crate::testutil::prop::PropRng, depth: usize) -> Value {
        match rng.range(if depth > 2 { 4 } else { 6 }) {
            0 => Value::Null,
            1 => Value::Bool(rng.bool()),
            2 => Value::Number(rng.i64_in(-100000, 100000) as f64 / 100.0),
            3 => Value::String(rng.string(12)),
            4 => Value::Array((0..rng.range(4)).map(|_| arbitrary(rng, depth + 1)).collect()),
            _ => {
                let mut m = Map::new();
                for _ in 0..rng.range(3) {
                    m.insert(rng.string(6), arbitrary(rng, depth + 1));
                }
                Value::Object(m)
            }
        }
    }
    Runner::new("grammar_vs_serializer", 200).run(|rng| {
        let v = arbitrary(rng, 0);
        let text = to_string(&v);
        let mut m = GrammarMatcher::new(g.clone());
        if !m.advance_bytes(text.as_bytes()) {
            return Err(format!("grammar rejected serializer output: {text}"));
        }
        if !m.is_accepting() {
            return Err(format!("grammar not accepting after: {text}"));
        }
        Ok(())
    });
}

// -- regex -> grammar compiler ------------------------------------------------

fn regex(p: &str) -> Rc<Grammar> {
    Rc::new(regex_to_grammar(p).unwrap())
}

#[test]
fn regex_literals_classes_postfix() {
    let g = regex("ab*c");
    assert!(accepts(&g, "ac"));
    assert!(accepts(&g, "abbbc"));
    assert!(!accepts(&g, "a"));
    assert!(rejects_prefix(&g, "x"));

    let g = regex("[a-f0-9]+");
    assert!(accepts(&g, "deadbeef42"));
    assert!(!accepts(&g, ""));
    assert!(rejects_prefix(&g, "g"));

    let g = regex("colou?r");
    assert!(accepts(&g, "color"));
    assert!(accepts(&g, "colour"));
}

#[test]
fn regex_counted_repetition_and_alternation() {
    let g = regex("^(ab|cd){2,3}$");
    assert!(accepts(&g, "abab"));
    assert!(accepts(&g, "abcdab"));
    assert!(!accepts(&g, "ab"));
    assert!(!accepts(&g, "abababab"));

    let g = regex("a{3}");
    assert!(accepts(&g, "aaa"));
    assert!(!accepts(&g, "aa"));
    assert!(!accepts(&g, "aaaa"));

    let g = regex("x{2,}");
    assert!(accepts(&g, "xx"));
    assert!(accepts(&g, "xxxxxx"));
    assert!(!accepts(&g, "x"));
}

#[test]
fn regex_anchored_and_json_safe_alphabet() {
    // Anchors are epsilon; the language is always the full string.
    let g = regex("^v[0-9]+\\.[0-9]+$");
    assert!(accepts(&g, "v1.12"));
    assert!(!accepts(&g, "v1"));

    // `.` and negated classes complement within printable-minus-quote.
    let g = regex(".+");
    assert!(accepts(&g, "any text!"));
    assert!(rejects_prefix(&g, "\""));
    assert!(rejects_prefix(&g, "\n"));
    let g = regex("[^0-9]");
    assert!(accepts(&g, "z"));
    assert!(rejects_prefix(&g, "5"));
    assert!(rejects_prefix(&g, "\\"));
}

#[test]
fn regex_errors_are_structured() {
    for bad in [
        "a(?=b)",     // lookahead
        "(a",         // unbalanced
        "a)",         // unbalanced
        "*a",         // nothing to repeat
        "[z-a]",      // inverted range
        "[]",         // empty class
        "a{5,2}",     // max < min
        "a{2000}",    // over MAX_REPEAT
        "\\n",        // raw control char can't sit unescaped in JSON
        "a\"b",       // quote would need a JSON escape
        "caf\u{e9}",  // non-ASCII pattern
    ] {
        assert!(
            matches!(regex_to_grammar(bad), Err(GrammarError::Schema(_))),
            "{bad:?} should be a structured Schema error"
        );
    }
}

// -- extended JSON-Schema keyword families ------------------------------------

#[test]
fn schema_type_arrays() {
    let g = schema(r#"{"type": ["integer", "null"]}"#);
    assert!(accepts(&g, "3"));
    assert!(accepts(&g, "-12"));
    assert!(accepts(&g, "null"));
    assert!(!accepts(&g, "3.5"));
    assert!(rejects_prefix(&g, "\"x\""));

    // Sibling keywords apply to the branch they constrain.
    let g = schema(r#"{"type": ["string", "null"], "maxLength": 2}"#);
    assert!(accepts(&g, "\"ab\""));
    assert!(accepts(&g, "null"));
    assert!(!accepts(&g, "\"abc\""));
}

#[test]
fn schema_integer_bounds_compile_to_digit_ranges() {
    let g = schema(r#"{"type": "integer", "minimum": 1, "maximum": 40}"#);
    for ok in ["1", "9", "12", "40"] {
        assert!(accepts(&g, ok), "{ok}");
    }
    for bad in ["0", "41", "-1", "07"] {
        assert!(!accepts(&g, bad), "{bad} wrongly accepted");
    }

    let g = schema(r#"{"type": "integer", "minimum": -25, "maximum": -3}"#);
    assert!(accepts(&g, "-25"));
    assert!(accepts(&g, "-3"));
    assert!(!accepts(&g, "-2"));
    assert!(!accepts(&g, "-26"));
    assert!(!accepts(&g, "0"));

    let g = schema(r#"{"type": "integer", "exclusiveMinimum": 0, "exclusiveMaximum": 100}"#);
    assert!(accepts(&g, "1"));
    assert!(accepts(&g, "99"));
    assert!(!accepts(&g, "0"));
    assert!(!accepts(&g, "100"));

    // One-sided bound: unbounded above.
    let g = schema(r#"{"type": "integer", "minimum": 200}"#);
    assert!(accepts(&g, "200"));
    assert!(accepts(&g, "123456"));
    assert!(!accepts(&g, "199"));
}

#[test]
fn schema_number_bounds_with_decimals() {
    let g = schema(r#"{"type": "number", "minimum": 0, "maximum": 10}"#);
    for ok in ["0", "10", "3.5", "0.25", "9.999"] {
        assert!(accepts(&g, ok), "{ok}");
    }
    for bad in ["-0.5", "10.1", "11", "1e2"] {
        assert!(!accepts(&g, bad), "{bad} wrongly accepted");
    }

    // Exclusive bound at the boundary value needs a nonzero fraction.
    let g = schema(r#"{"type": "number", "exclusiveMinimum": 0, "maximum": 2}"#);
    assert!(accepts(&g, "0.5"));
    assert!(accepts(&g, "0.001"));
    assert!(accepts(&g, "2"));
    assert!(!accepts(&g, "0"));
    assert!(!accepts(&g, "0.0"));
    assert!(!accepts(&g, "2.1"));

    let g = schema(r#"{"type": "number", "minimum": -2, "exclusiveMaximum": 0}"#);
    assert!(accepts(&g, "-0.5"));
    assert!(accepts(&g, "-2"));
    assert!(!accepts(&g, "0"));
    assert!(!accepts(&g, "-2.5"));
}

#[test]
fn schema_string_length_counts_code_points() {
    let g = schema(r#"{"type": "string", "minLength": 2, "maxLength": 3}"#);
    assert!(accepts(&g, "\"ab\""));
    assert!(accepts(&g, "\"abc\""));
    assert!(accepts(&g, "\"日本語\""));
    assert!(accepts(&g, "\"a\\nb\""));
    assert!(!accepts(&g, "\"a\""));
    assert!(!accepts(&g, "\"abcd\""));
    assert!(!accepts(&g, "\"\""));
}

#[test]
fn schema_pattern_and_formats() {
    let g = schema(r#"{"type": "string", "pattern": "^[A-Z]{2}-[0-9]{3}$"}"#);
    assert!(accepts(&g, "\"AB-123\""));
    assert!(!accepts(&g, "\"ab-123\""));
    assert!(!accepts(&g, "\"AB-12\""));

    let g = schema(r#"{"type": "string", "format": "date"}"#);
    assert!(accepts(&g, "\"2024-02-29\""));
    assert!(!accepts(&g, "\"2024-13-01\""));
    assert!(!accepts(&g, "\"2024-1-1\""));

    let g = schema(r#"{"type": "string", "format": "date-time"}"#);
    assert!(accepts(&g, "\"2024-01-15T10:30:00Z\""));
    assert!(accepts(&g, "\"2024-01-15T10:30:00.123+05:30\""));
    assert!(!accepts(&g, "\"2024-01-15 10:30:00Z\""));

    let g = schema(r#"{"type": "string", "format": "uuid"}"#);
    assert!(accepts(&g, "\"123e4567-e89b-12d3-a456-426614174000\""));
    assert!(!accepts(&g, "\"123E4567-E89B-12D3-A456-426614174000\""));

    let g = schema(r#"{"type": "string", "format": "email"}"#);
    assert!(accepts(&g, "\"a.b+tag@example.co\""));
    assert!(!accepts(&g, "\"no-at-sign\""));

    // Unknown formats are annotations: plain string.
    let g = schema(r#"{"type": "string", "format": "hostname"}"#);
    assert!(accepts(&g, "\"anything at all\""));
}

#[test]
fn schema_all_of_merges() {
    let g = schema(
        r#"{"allOf": [
            {"type": "object", "properties": {"a": {"type": "integer"}}, "required": ["a"]},
            {"type": "object", "properties": {"b": {"type": "boolean"}}, "required": ["b"]}
        ]}"#,
    );
    assert!(accepts(&g, r#"{"a":1,"b":true}"#));
    assert!(!accepts(&g, r#"{"a":1}"#));
    assert!(!accepts(&g, r#"{"b":true}"#));

    let g = schema(r#"{"type": "integer", "allOf": [{"minimum": 0}, {"maximum": 10}]}"#);
    assert!(accepts(&g, "7"));
    assert!(!accepts(&g, "11"));

    for bad in [
        r#"{"allOf": [{"type": "string"}, {"type": "integer"}]}"#,
        r#"{"allOf": [{"const": 1}, {"const": 2}]}"#,
        r#"{"type": "integer", "allOf": [{"minimum": 5}, {"maximum": 2}]}"#,
    ] {
        assert!(
            matches!(schema_to_grammar(&parse(bad).unwrap()), Err(GrammarError::Schema(_))),
            "{bad}"
        );
    }
}

#[test]
fn schema_one_of_requires_disjoint_branches() {
    let g = schema(r#"{"oneOf": [{"type": "integer"}, {"type": "string"}]}"#);
    assert!(accepts(&g, "7"));
    assert!(accepts(&g, "\"x\""));
    assert!(!accepts(&g, "true"));

    let g = schema(r#"{"oneOf": [{"const": "a"}, {"enum": ["b", "c"]}]}"#);
    assert!(accepts(&g, "\"a\""));
    assert!(accepts(&g, "\"c\""));
    assert!(!accepts(&g, "\"d\""));

    // integer and number overlap (3 matches both) -> structured error.
    for bad in [
        r#"{"oneOf": [{"type": "integer"}, {"type": "number"}]}"#,
        r#"{"oneOf": [{"type": "string"}, {}]}"#,
        r#"{"oneOf": [{"const": "a"}, {"enum": ["a", "b"]}]}"#,
    ] {
        assert!(
            matches!(schema_to_grammar(&parse(bad).unwrap()), Err(GrammarError::Schema(_))),
            "{bad}"
        );
    }
}

#[test]
fn schema_additional_properties_maps() {
    let g = schema(r#"{"type": "object", "additionalProperties": {"type": "integer"}}"#);
    assert!(accepts(&g, "{}"));
    assert!(accepts(&g, r#"{"a":1}"#));
    assert!(accepts(&g, r#"{"a":1,"b":-2}"#));
    assert!(!accepts(&g, r#"{"a":true}"#));

    // Bare object type admits arbitrary members.
    let g = schema(r#"{"type": "object"}"#);
    assert!(accepts(&g, "{}"));
    assert!(accepts(&g, r#"{"x":[1,{"y":null}]}"#));

    // additionalProperties: false without properties pins the empty object.
    let g = schema(r#"{"type": "object", "additionalProperties": false}"#);
    assert!(accepts(&g, "{}"));
    assert!(!accepts(&g, r#"{"a":1}"#));
}

#[test]
fn schema_prefix_items_tuples() {
    let g = schema(
        r#"{"type": "array",
            "prefixItems": [{"type": "string"}, {"type": "integer"}],
            "items": false}"#,
    );
    assert!(accepts(&g, "[]"));
    assert!(accepts(&g, r#"["x"]"#));
    assert!(accepts(&g, r#"["x",3]"#));
    assert!(!accepts(&g, r#"["x",3,4]"#));
    assert!(!accepts(&g, "[3]"));

    let g = schema(
        r#"{"type": "array",
            "prefixItems": [{"type": "integer"}],
            "items": {"type": "boolean"},
            "minItems": 1}"#,
    );
    assert!(accepts(&g, "[1]"));
    assert!(accepts(&g, "[1,true,false]"));
    assert!(!accepts(&g, "[]"));
    assert!(!accepts(&g, "[true]"));
    assert!(!accepts(&g, "[1,2]"));
}

#[test]
fn ebnf_counted_repetition() {
    let g = Rc::new(parse_ebnf(r#"root ::= "a"{2,4}"#).unwrap());
    assert!(accepts(&g, "aa"));
    assert!(accepts(&g, "aaaa"));
    assert!(!accepts(&g, "a"));
    assert!(!accepts(&g, "aaaaa"));

    let g = Rc::new(parse_ebnf("root ::= [0-9]{3}").unwrap());
    assert!(accepts(&g, "042"));
    assert!(!accepts(&g, "42"));
    assert!(!accepts(&g, "0424"));

    let g = Rc::new(parse_ebnf(r#"root ::= "x"{2,}"#).unwrap());
    assert!(accepts(&g, "xx"));
    assert!(accepts(&g, "xxxxx"));
    assert!(!accepts(&g, "x"));

    assert!(parse_ebnf(r#"root ::= "a"{5,2}"#).is_err());
    assert!(parse_ebnf(r#"root ::= "a"{999999}"#).is_err());
}
