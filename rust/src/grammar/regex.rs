//! Bounded regex -> grammar compiler (the `pattern` / `format` keywords of
//! the JSON-Schema frontend, DESIGN.md §2).
//!
//! Supported syntax: literals, `.`, character classes (`[a-z0-9_]`,
//! negation, ranges, `\d \w \s`), groups `( )` / `(?: )`, alternation
//! `|`, and the postfix operators `* + ? {m} {m,} {m,n}`. A leading `^`
//! and trailing `$` are accepted and ignored: the compiled grammar is
//! **always anchored** (it describes the complete string between the JSON
//! quotes). Mid-pattern anchors, backreferences, and lookaround are
//! rejected with [`GrammarError::Schema`].
//!
//! The alphabet is the *JSON-safe* byte set — printable ASCII `0x20..=0x7E`
//! minus `"` and `\` — so every string the grammar derives can be emitted
//! inside a JSON string without escaping. `.` and negated classes are
//! complemented relative to that set; `\s` narrows to a single space
//! (raw tabs/newlines are not legal inside a JSON string). Repetition
//! counts are capped and the total expansion is budgeted, so adversarial
//! patterns fail with a structured error instead of exhausting memory.

use super::grammar::{ByteClass, Grammar, GrammarError, Sym};

/// Longest accepted pattern, in bytes.
pub const MAX_PATTERN_LEN: usize = 1024;
/// Largest `{m,n}` repetition count.
const MAX_REPEAT: usize = 1024;
/// Total symbol-expansion budget per pattern (guards `("x"{999}){999}`).
const MAX_EXPANSION: usize = 65_536;

/// Compile an anchored regex into a byte-level [`Grammar`] (rule 0 is the
/// root). The language is the set of complete strings the pattern matches,
/// over the JSON-safe alphabet (printable ASCII minus `"` and `\`).
///
/// # Examples
///
/// ```
/// use std::rc::Rc;
/// use webllm::grammar::{regex_to_grammar, GrammarMatcher};
///
/// let g = Rc::new(regex_to_grammar("[A-Z]{2}-[0-9]{3}").unwrap());
///
/// let mut m = GrammarMatcher::new(g.clone());
/// assert!(m.advance_bytes(b"AB-123") && m.is_accepting());
///
/// // Anchored: a matching prefix with trailing garbage is rejected.
/// let mut m = GrammarMatcher::new(g);
/// assert!(!m.advance_bytes(b"AB-1234x"));
/// ```
///
/// Unsupported constructs produce [`GrammarError::Schema`]:
///
/// ```
/// use webllm::grammar::{regex_to_grammar, GrammarError};
///
/// assert!(matches!(regex_to_grammar("a(?=b)"), Err(GrammarError::Schema(_))));
/// ```
pub fn regex_to_grammar(pattern: &str) -> Result<Grammar, GrammarError> {
    let mut g = Grammar::new();
    let root = g.add_rule("root");
    debug_assert_eq!(root, 0);
    let seq = compile_fragment(&mut g, pattern, "regex")?;
    g.add_alt(0, seq);
    g.validate()?;
    Ok(g)
}

/// Compile `pattern` into a symbol sequence inside an existing grammar
/// (used by the schema compiler to inline `pattern`/`format` between the
/// JSON string quotes).
pub(crate) fn compile_fragment(
    g: &mut Grammar,
    pattern: &str,
    hint: &str,
) -> Result<Vec<Sym>, GrammarError> {
    if pattern.len() > MAX_PATTERN_LEN {
        return Err(GrammarError::Schema(format!(
            "regex: pattern longer than {MAX_PATTERN_LEN} bytes"
        )));
    }
    if !pattern.is_ascii() {
        return Err(GrammarError::Schema(
            "regex: non-ASCII patterns unsupported".into(),
        ));
    }
    let mut p = Rx { bytes: pattern.as_bytes(), pos: 0, g, hint, budget: MAX_EXPANSION };
    let alts = p.alternation()?;
    if p.pos != p.bytes.len() {
        return Err(p.err("unbalanced ')'"));
    }
    Ok(wrap_alts(p.g, alts, hint))
}

fn wrap_alts(g: &mut Grammar, mut alts: Vec<Vec<Sym>>, hint: &str) -> Vec<Sym> {
    if alts.len() == 1 {
        alts.pop().unwrap()
    } else {
        vec![g.choice(alts, hint)]
    }
}

/// JSON-safe: printable ASCII minus `"` and `\` — emittable unescaped.
fn is_safe(b: u8) -> bool {
    (0x20..=0x7E).contains(&b) && b != b'"' && b != b'\\'
}

fn safe_class() -> ByteClass {
    ByteClass { ranges: vec![(0x20, 0x21), (0x23, 0x5B), (0x5D, 0x7E)], negated: false }
}

fn is_meta(b: u8) -> bool {
    matches!(
        b,
        b'(' | b')' | b'[' | b']' | b'{' | b'}' | b'*' | b'+' | b'?' | b'|' | b'.' | b'^' | b'$'
    )
}

struct Rx<'a, 'g> {
    bytes: &'a [u8],
    pos: usize,
    g: &'g mut Grammar,
    hint: &'a str,
    budget: usize,
}

impl<'a, 'g> Rx<'a, 'g> {
    fn err(&self, m: impl Into<String>) -> GrammarError {
        GrammarError::Schema(format!("regex: {} (at byte {} of pattern)", m.into(), self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn charge(&mut self, n: usize) -> Result<(), GrammarError> {
        if n > self.budget {
            return Err(self.err("pattern expansion exceeds budget"));
        }
        self.budget -= n;
        Ok(())
    }

    fn alternation(&mut self) -> Result<Vec<Vec<Sym>>, GrammarError> {
        let mut alts = vec![self.concat()?];
        while self.peek() == Some(b'|') {
            self.pos += 1;
            alts.push(self.concat()?);
        }
        Ok(alts)
    }

    fn concat(&mut self) -> Result<Vec<Sym>, GrammarError> {
        let mut seq = Vec::new();
        loop {
            match self.peek() {
                None | Some(b'|') | Some(b')') => return Ok(seq),
                Some(b'^') => {
                    // Zero-width anchor: a no-op (the grammar is anchored),
                    // accepted only at the start of a branch.
                    if !seq.is_empty() {
                        return Err(self.err("'^' only supported at the start"));
                    }
                    self.pos += 1;
                }
                Some(b'$') => {
                    self.pos += 1;
                    match self.peek() {
                        None | Some(b'|') | Some(b')') => {}
                        _ => return Err(self.err("'$' only supported at the end")),
                    }
                }
                _ => {
                    let atom = self.atom()?;
                    let expanded = self.postfix(atom)?;
                    seq.extend(expanded);
                }
            }
        }
    }

    fn atom(&mut self) -> Result<Vec<Sym>, GrammarError> {
        self.charge(1)?;
        match self.peek().expect("concat checked for end") {
            b'(' => {
                self.pos += 1;
                if self.peek() == Some(b'?') {
                    if self.bytes.get(self.pos + 1) == Some(&b':') {
                        self.pos += 2; // non-capturing group marker
                    } else {
                        return Err(self.err("unsupported '(?' construct (lookaround/flags)"));
                    }
                }
                let alts = self.alternation()?;
                if self.peek() != Some(b')') {
                    return Err(self.err("expected ')'"));
                }
                self.pos += 1;
                Ok(wrap_alts(self.g, alts, self.hint))
            }
            b'[' => Ok(vec![Sym::Class(self.class()?)]),
            b'.' => {
                self.pos += 1;
                Ok(vec![Sym::Class(safe_class())])
            }
            b'\\' => {
                self.pos += 1;
                let c = self.peek().ok_or_else(|| self.err("dangling escape"))?;
                self.pos += 1;
                Ok(vec![Sym::Class(self.escape_class(c)?)])
            }
            b'*' | b'+' | b'?' | b'{' => Err(self.err("repetition with nothing to repeat")),
            c if is_safe(c) && !is_meta(c) => {
                self.pos += 1;
                Ok(vec![Sym::Class(ByteClass::byte(c))])
            }
            c => Err(self.err(format!(
                "character 0x{c:02x} not representable in an unescaped JSON string"
            ))),
        }
    }

    /// A `\x` escape outside a class, as a byte class.
    fn escape_class(&self, c: u8) -> Result<ByteClass, GrammarError> {
        Ok(match c {
            b'd' => ByteClass { ranges: vec![(b'0', b'9')], negated: false },
            b'w' => ByteClass {
                ranges: vec![(b'0', b'9'), (b'A', b'Z'), (b'_', b'_'), (b'a', b'z')],
                negated: false,
            },
            // Raw tab/newline are illegal inside a JSON string; the
            // JSON-safe narrowing of \s is a single space.
            b's' => ByteClass::byte(b' '),
            c if is_safe(c) && !c.is_ascii_alphanumeric() => ByteClass::byte(c),
            b'\\' | b'"' | b'n' | b't' | b'r' | b'f' | b'b' | b'0' => {
                return Err(self.err(format!(
                    "escape '\\{}' not representable in an unescaped JSON string",
                    c as char
                )))
            }
            other => return Err(self.err(format!("unknown escape '\\{}'", other as char))),
        })
    }

    /// `[...]` class, intersected with the JSON-safe alphabet.
    fn class(&mut self) -> Result<ByteClass, GrammarError> {
        self.pos += 1; // '['
        let negated = if self.peek() == Some(b'^') {
            self.pos += 1;
            true
        } else {
            false
        };
        let mut set = [false; 128];
        let mut any = false;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated class")),
                Some(b']') => {
                    if !any {
                        return Err(self.err("empty character class"));
                    }
                    self.pos += 1;
                    break;
                }
                _ => {
                    if let Some(lo) = self.class_item(&mut set)? {
                        if self.peek() == Some(b'-')
                            && self.bytes.get(self.pos + 1).map_or(false, |&c| c != b']')
                        {
                            self.pos += 1;
                            let hi = self
                                .class_item(&mut set)?
                                .ok_or_else(|| self.err("invalid range endpoint"))?;
                            if hi < lo {
                                return Err(self.err("inverted range"));
                            }
                            for b in lo..=hi {
                                if (b as usize) < 128 {
                                    set[b as usize] = true;
                                }
                            }
                        } else if (lo as usize) < 128 {
                            set[lo as usize] = true;
                        }
                    }
                    any = true;
                }
            }
        }
        // Complement relative to — and intersect with — the JSON-safe set.
        let mut ranges: Vec<(u8, u8)> = Vec::new();
        let mut run: Option<(u8, u8)> = None;
        for b in 0u8..128 {
            let inside = set[b as usize] != negated;
            if inside && is_safe(b) {
                run = match run {
                    Some((lo, hi)) if hi + 1 == b => Some((lo, b)),
                    Some(r) => {
                        ranges.push(r);
                        Some((b, b))
                    }
                    None => Some((b, b)),
                };
            }
        }
        if let Some(r) = run {
            ranges.push(r);
        }
        if ranges.is_empty() {
            return Err(self.err("character class matches no JSON-safe character"));
        }
        Ok(ByteClass { ranges, negated: false })
    }

    /// One class member: a literal/escaped byte (`Some`) or a perl class
    /// that was added to `set` directly (`None`).
    fn class_item(&mut self, set: &mut [bool; 128]) -> Result<Option<u8>, GrammarError> {
        match self.peek() {
            None => Err(self.err("unterminated class")),
            Some(b'\\') => {
                self.pos += 1;
                let c = self.peek().ok_or_else(|| self.err("dangling escape"))?;
                self.pos += 1;
                match c {
                    b'd' => {
                        for b in b'0'..=b'9' {
                            set[b as usize] = true;
                        }
                        Ok(None)
                    }
                    b'w' => {
                        for b in (b'0'..=b'9').chain(b'A'..=b'Z').chain(b'a'..=b'z') {
                            set[b as usize] = true;
                        }
                        set[b'_' as usize] = true;
                        Ok(None)
                    }
                    b's' => {
                        set[b' ' as usize] = true;
                        Ok(None)
                    }
                    b'n' | b't' | b'r' | b'f' => Err(self.err(format!(
                        "escape '\\{}' not representable in an unescaped JSON string",
                        c as char
                    ))),
                    other => Ok(Some(other)),
                }
            }
            Some(c) => {
                self.pos += 1;
                Ok(Some(c))
            }
        }
    }

    fn postfix(&mut self, atom: Vec<Sym>) -> Result<Vec<Sym>, GrammarError> {
        if atom.is_empty() {
            // Repetition of an empty group derives only ε; desugaring it
            // would build an epsilon-cycle rule, so short-circuit.
            if matches!(self.peek(), Some(b'*' | b'+' | b'?')) {
                self.pos += 1;
            } else if self.peek() == Some(b'{') {
                while self.peek().is_some() && self.peek() != Some(b'}') {
                    self.pos += 1;
                }
                if self.peek() != Some(b'}') {
                    return Err(self.err("expected '}' in repetition"));
                }
                self.pos += 1;
            }
            return Ok(atom);
        }
        match self.peek() {
            Some(b'*') => {
                self.pos += 1;
                Ok(vec![self.g.star(atom, self.hint)])
            }
            Some(b'+') => {
                self.pos += 1;
                Ok(self.g.plus(atom, self.hint))
            }
            Some(b'?') => {
                self.pos += 1;
                Ok(vec![self.g.opt(atom, self.hint)])
            }
            Some(b'{') => {
                self.pos += 1;
                let min = self.number()?;
                let max = match self.peek() {
                    Some(b'}') => Some(min),
                    Some(b',') => {
                        self.pos += 1;
                        if self.peek() == Some(b'}') {
                            None
                        } else {
                            Some(self.number()?)
                        }
                    }
                    _ => return Err(self.err("expected ',' or '}' in repetition")),
                };
                if self.peek() != Some(b'}') {
                    return Err(self.err("expected '}' in repetition"));
                }
                self.pos += 1;
                if min > MAX_REPEAT || max.map_or(false, |n| n > MAX_REPEAT) {
                    return Err(self.err(format!("repetition count exceeds {MAX_REPEAT}")));
                }
                if let Some(n) = max {
                    if n < min {
                        return Err(self.err("repetition max < min"));
                    }
                }
                let copies = max.unwrap_or(min) + 1;
                self.charge(atom.len().max(1).saturating_mul(copies))?;
                Ok(self.g.repeat(atom, min, max, self.hint))
            }
            _ => Ok(atom),
        }
    }

    fn number(&mut self) -> Result<usize, GrammarError> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start || self.pos - start > 7 {
            return Err(self.err("expected repetition count"));
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .unwrap()
            .parse()
            .map_err(|_| self.err("bad repetition count"))
    }
}
