//! Stand-in for the patched `xla-rs` 0.1.6 PJRT bindings.
//!
//! The webllm runtime layer (`webllm::runtime`) is written against the
//! patched vendored `xla` crate described in DESIGN.md §6: the patch makes
//! `PjRtLoadedExecutable::execute_b` return its result *untupled* as
//! `Vec<Vec<PjRtBuffer>>` (one `Vec<PjRtBuffer>` per replica) so KV-cache
//! buffers chain between steps without a host round-trip.
//!
//! This crate reproduces that exact API surface in pure Rust so the whole
//! workspace builds and tests offline, with no C++ XLA toolchain:
//!
//! * the *host side* is fully functional — typed buffers, literals, and
//!   round-trips (`buffer_from_host_buffer` → `to_literal_sync` →
//!   `to_vec::<T>()`) behave like the real thing;
//! * the *device side* (HLO compilation / execution) reports
//!   [`Error::BackendUnavailable`]. Everything execution-dependent in
//!   webllm (engine e2e tests, Table-1 benches) already gates on built
//!   artifacts being present, so `cargo test -q` passes without a PJRT
//!   plugin.
//!
//! Dropping in the real patched bindings is a one-line change in
//! `rust/Cargo.toml` (point the `xla` path elsewhere); no webllm source
//! changes are required.

use std::fmt;
use std::rc::Rc;

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

/// Errors surfaced by the bindings. Only the variants webllm constructs or
/// matches are load-bearing; the rest exist for API fidelity.
#[derive(Debug)]
pub enum Error {
    /// An element type an operation cannot handle.
    UnsupportedElementType {
        ty: PrimitiveType,
        op: &'static str,
    },
    /// Compilation/execution requested but no PJRT plugin is linked in.
    BackendUnavailable(&'static str),
    /// Host-side usage error (shape/dtype mismatch, I/O, ...).
    Internal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnsupportedElementType { ty, op } => {
                write!(f, "unsupported element type {ty:?} for op {op}")
            }
            Error::BackendUnavailable(op) => write!(
                f,
                "PJRT backend unavailable for '{op}': this build uses the pure-Rust \
                 xla API stub (rust/vendor/xla); link the patched xla-rs bindings to \
                 compile and execute HLO"
            ),
            Error::Internal(m) => write!(f, "xla: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// element types
// ---------------------------------------------------------------------------

/// XLA's wire-level type tags (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    Invalid = 0,
    Pred = 1,
    S8 = 2,
    S16 = 3,
    S32 = 4,
    S64 = 5,
    U8 = 6,
    U16 = 7,
    U32 = 8,
    U64 = 9,
    F16 = 10,
    F32 = 11,
    Bf16 = 16,
    F64 = 12,
}

/// Host-visible element types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    pub fn primitive_type(&self) -> PrimitiveType {
        match self {
            ElementType::Pred => PrimitiveType::Pred,
            ElementType::S8 => PrimitiveType::S8,
            ElementType::S16 => PrimitiveType::S16,
            ElementType::S32 => PrimitiveType::S32,
            ElementType::S64 => PrimitiveType::S64,
            ElementType::U8 => PrimitiveType::U8,
            ElementType::U16 => PrimitiveType::U16,
            ElementType::U32 => PrimitiveType::U32,
            ElementType::U64 => PrimitiveType::U64,
            ElementType::F16 => PrimitiveType::F16,
            ElementType::Bf16 => PrimitiveType::Bf16,
            ElementType::F32 => PrimitiveType::F32,
            ElementType::F64 => PrimitiveType::F64,
        }
    }

    /// Size of one element in bytes (packed sub-byte types round up).
    pub fn element_size_in_bytes(&self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Rust types that map onto an [`ElementType`] and can round-trip through
/// buffers/literals.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn write_le(&self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $et:expr) => {
        impl NativeType for $t {
            const ELEMENT_TYPE: ElementType = $et;
            fn write_le(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn read_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("element width"))
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(f64, ElementType::F64);
native!(i32, ElementType::S32);
native!(i64, ElementType::S64);
native!(u32, ElementType::U32);
native!(u64, ElementType::U64);

// ---------------------------------------------------------------------------
// client / buffers / literals
// ---------------------------------------------------------------------------

/// Handle to a PJRT client. `Rc`-based and deliberately `!Send`, matching
/// the real bindings (webllm keeps one client per worker thread).
#[derive(Clone)]
pub struct PjRtClient {
    _state: Rc<()>,
}

impl PjRtClient {
    /// The CPU client. Host-side operations are fully functional.
    pub fn cpu() -> Result<Self> {
        Ok(Self { _state: Rc::new(()) })
    }

    /// Upload a typed host tensor.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let expect: usize = dims.iter().product();
        if expect != data.len() {
            return Err(Error::Internal(format!(
                "buffer_from_host_buffer: {} elements for shape {dims:?}",
                data.len()
            )));
        }
        let mut bytes = Vec::with_capacity(data.len() * T::ELEMENT_TYPE.element_size_in_bytes());
        for v in data {
            v.write_le(&mut bytes);
        }
        Ok(PjRtBuffer {
            inner: Rc::new(BufferData {
                ty: T::ELEMENT_TYPE,
                dims: dims.to_vec(),
                bytes,
            }),
        })
    }

    /// Compile an HLO computation. Requires a real PJRT plugin.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable("compile"))
    }
}

struct BufferData {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

/// Device-resident buffer (host-backed in the stub).
pub struct PjRtBuffer {
    inner: Rc<BufferData>,
}

impl PjRtBuffer {
    pub fn element_type(&self) -> ElementType {
        self.inner.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.inner.dims
    }

    /// Copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal {
            ty: self.inner.ty,
            dims: self.inner.dims.clone(),
            bytes: self.inner.bytes.clone(),
        })
    }
}

/// A host tensor.
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Reinterpret as a typed vector; the requested type must match.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(Error::Internal(format!(
                "to_vec: literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        let w = self.ty.element_size_in_bytes();
        Ok(self.bytes.chunks_exact(w).map(T::read_le).collect())
    }
}

// ---------------------------------------------------------------------------
// HLO plumbing
// ---------------------------------------------------------------------------

/// Parsed HLO module text. The stub stores the source verbatim; parsing
/// and verification happen in the real bindings' C++ layer.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Internal(format!("read {}: {e}", path.display())))?;
        Ok(Self { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self {
            _text: proto.text.clone(),
        }
    }
}

/// A compiled executable. Unconstructible in the stub (compile fails), but
/// the type and its methods exist so call sites typecheck.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers. The patched bindings return
    /// results untupled: one `Vec<PjRtBuffer>` per replica.
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("execute"))
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_literal_roundtrip_f32() {
        let c = PjRtClient::cpu().unwrap();
        let data = [1.5f32, -2.0, 0.0, 3.25, 8.0, -0.5];
        let b = c.buffer_from_host_buffer(&data, &[2, 3], None).unwrap();
        assert_eq!(b.dims(), &[2, 3]);
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn buffer_literal_roundtrip_i32_u32() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[-7i32, 9], &[2], None).unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![-7, 9]);
        let b = c.buffer_from_host_buffer(&[7u32, 9], &[2], None).unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<u32>().unwrap(), vec![7, 9]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32; 5], &[2, 3], None).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer(&[1.0f32; 4], &[4], None).unwrap();
        assert!(b.to_literal_sync().unwrap().to_vec::<i32>().is_err());
    }

    #[test]
    fn compile_reports_backend_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }
}
